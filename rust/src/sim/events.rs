//! Event queue for the discrete-event simulator: a binary min-heap keyed
//! on (time, sequence) — the sequence number breaks ties deterministically
//! so runs replay bit-for-bit.

/// Min-heap of timed events.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: Vec<(f64, u64, E)>,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: Vec::new(),
            seq: 0,
        }
    }

    pub fn push(&mut self, time: f64, event: E) {
        debug_assert!(time.is_finite(), "event time must be finite");
        self.heap.push((time, self.seq, event));
        self.seq += 1;
        self.sift_up(self.heap.len() - 1);
    }

    pub fn pop(&mut self) -> Option<(f64, E)> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let (t, _, e) = self.heap.pop().unwrap();
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        Some((t, e))
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.first().map(|(t, _, _)| *t)
    }

    /// Next event (time + payload ref) without removing it.
    pub fn peek(&self) -> Option<(f64, &E)> {
        self.heap.first().map(|(t, _, e)| (*t, e))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Heap order: (time, seq) ascending.  `total_cmp` keeps the
    /// comparison a total order even on a NaN time (consistent with the
    /// PR-3 NaN-safe sweep of the scheduler/predictor sorts) — the
    /// finite-time `debug_assert` in [`EventQueue::push`] still flags the
    /// bug in debug builds, but release builds order deterministically
    /// instead of panicking mid-run.  Behaviour-preserving for every
    /// time the sim produces: `total_cmp` and `partial_cmp` agree on all
    /// non-NaN, non-signed-zero floats, and sim times are sums of
    /// non-negative terms (never `-0.0`; if one ever appeared it would
    /// deterministically sort before `+0.0` — see the signed-zero test).
    #[inline]
    fn less(&self, a: usize, b: usize) -> bool {
        let (ta, sa, _) = &self.heap[a];
        let (tb, sb, _) = &self.heap[b];
        match ta.total_cmp(tb) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => sa < sb,
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.less(i, parent) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut smallest = i;
            if l < self.heap.len() && self.less(l, smallest) {
                smallest = l;
            }
            if r < self.heap.len() && self.less(r, smallest) {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        for &t in &[5.0, 1.0, 3.0, 2.0, 4.0] {
            q.push(t, t as u32);
        }
        let mut got = Vec::new();
        while let Some((t, _)) = q.pop() {
            got.push(t);
        }
        assert_eq!(got, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, "a");
        q.push(1.0, "b");
        q.push(1.0, "c");
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b");
        assert_eq!(q.pop().unwrap().1, "c");
    }

    #[test]
    fn heap_property_random() {
        prop_check(100, |rng| {
            let mut q = EventQueue::new();
            let n = rng.range_usize(1, 200);
            for i in 0..n {
                q.push(rng.f64() * 100.0, i);
            }
            let mut last = f64::NEG_INFINITY;
            let mut count = 0;
            while let Some((t, _)) = q.pop() {
                assert!(t >= last);
                last = t;
                count += 1;
            }
            assert_eq!(count, n);
        });
    }

    #[test]
    fn signed_zero_orders_deterministically() {
        // total_cmp puts -0.0 before +0.0 from either insertion order —
        // the point of the NaN-safe sweep is that ordering never depends
        // on push sequence for distinct bit patterns.
        for flip in [false, true] {
            let mut q = EventQueue::new();
            if flip {
                q.push(0.0, "pos");
                q.push(-0.0, "neg");
            } else {
                q.push(-0.0, "neg");
                q.push(0.0, "pos");
            }
            assert_eq!(q.pop().unwrap().1, "neg");
            assert_eq!(q.pop().unwrap().1, "pos");
        }
    }

    #[test]
    fn peek_matches_next_pop() {
        let mut q = EventQueue::new();
        q.push(3.0, "b");
        q.push(1.0, "a");
        let (t, e) = q.peek().unwrap();
        assert_eq!((t, *e), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.peek().unwrap().0, 3.0);
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(10.0, 1);
        q.push(5.0, 2);
        assert_eq!(q.pop().unwrap().0, 5.0);
        q.push(1.0, 3);
        assert_eq!(q.pop().unwrap().0, 1.0);
        assert_eq!(q.pop().unwrap().0, 10.0);
        assert!(q.pop().is_none());
    }
}
