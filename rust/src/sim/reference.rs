//! The **owned-`Request` reference pipeline** — the pre-zero-copy shape
//! of the Magnus simulator, kept alive for two jobs:
//!
//! 1. **Golden equivalence.**  This module carries owned `Request`
//!    clones end to end (clone at arrival, clone into an owned log at
//!    completion), evaluates Algorithm 1 by scanning batch members with
//!    the raw Eq. 2–5 formulas (`batch::wma`), ranks batches with fresh
//!    `scheduler::select` views, and replicates the continuous-learning
//!    sweeps over its own owned logs.  It shares none of the compact
//!    pipeline's incremental structures, so
//!    `tests/store_equivalence.rs` comparing the two bit-for-bit is a
//!    genuine cross-implementation golden, not a tautology.
//! 2. **Scale baseline.**  `benches/bench_sim`'s scale mode times this
//!    path against the `TraceStore` path (`BENCH_scale.json`).  Note
//!    what the gap measures: this reference is the owned representation
//!    in its **pre-overhaul algorithmic shape** (naive member rescans,
//!    fresh linear-scan select), so the measured ratio bundles the
//!    PR 1–3 scheduling wins with PR 4's clone/alloc-tax removal — it is
//!    the whole-trajectory gap, not PR 4's share alone.  (An
//!    owned-representation run over the indexed batcher no longer
//!    exists: the batcher itself is meta-typed now.)  PR 4's own share
//!    shows in the peak-byte column and in the 10⁶ row the compact path
//!    completes.
//!
//! The only compact types it touches are at the engine boundary: the
//! `InferenceEngine` trait takes a `Batch` of metas, so each dispatch
//! materialises one from the owned members via [`RequestMeta::detached`]
//! (numbers only; the engine never resolves text).

use std::collections::VecDeque;

use crate::batch::wma::{mem_bytes, wma_gen, wma_wait};
use crate::batch::Batch;
use crate::config::{LearningConfig, ServingConfig};
use crate::engine::{BatchOutcome, InferenceEngine};
use crate::estimator::{BatchShape, ServingTimeEstimator};
use crate::logdb::{BatchLog, LogDb, RequestLog};
use crate::metrics::{RequestRecord, RunMetrics};
use crate::predictor::GenLenPredictor;
use crate::scheduler::{select, BatchView};
use crate::sim::events::EventQueue;
use crate::sim::magnus::{MagnusPolicy, SimOutput};
use crate::sim::OOM_RELOAD_S;
use crate::workload::{PredictedRequest, Request, RequestMeta};

/// A queued batch holding owned request clones.
struct OwnedBatch {
    id: u64,
    created_at: f64,
    insertable: bool,
    /// (owned request, predicted G') in insertion order.
    members: Vec<(Request, u32)>,
}

impl OwnedBatch {
    fn len(&self) -> u32 {
        self.members.iter().map(|m| m.0.request_len).max().unwrap_or(0)
    }

    fn predicted_gen(&self) -> u32 {
        self.members.iter().map(|m| m.1).max().unwrap_or(0)
    }

    fn true_gen(&self) -> u32 {
        self.members.iter().map(|m| m.0.gen_len).max().unwrap_or(0)
    }

    fn min_arrival(&self) -> f64 {
        self.members
            .iter()
            .map(|m| m.0.arrival)
            .fold(f64::INFINITY, f64::min)
    }

    fn predicted_shape(&self) -> BatchShape {
        BatchShape {
            batch_size: self.members.len() as u32,
            batch_len: self.len(),
            batch_gen_len: self.predicted_gen(),
        }
    }

    fn true_shape(&self) -> BatchShape {
        BatchShape {
            batch_size: self.members.len() as u32,
            batch_len: self.len(),
            batch_gen_len: self.true_gen(),
        }
    }

    /// The engine-boundary batch (numeric metas only).
    fn to_engine_batch(&self) -> Batch {
        Batch {
            id: self.id,
            requests: self
                .members
                .iter()
                .map(|(r, p)| PredictedRequest {
                    meta: RequestMeta::detached(r),
                    predicted_gen_len: *p,
                })
                .collect(),
            created_at: self.created_at,
            insertable: self.insertable,
        }
    }

    /// §III-C OOM split — same semantics as `Batch::split`: stable sort
    /// by request length, halve, both halves uninsertable, left keeps
    /// the id.
    fn split(mut self, next_id: u64) -> (OwnedBatch, OwnedBatch) {
        self.members.sort_by_key(|m| m.0.request_len);
        let half = self.members.len() / 2;
        let right = self.members.split_off(half);
        (
            OwnedBatch {
                id: self.id,
                created_at: self.created_at,
                insertable: false,
                members: self.members,
            },
            OwnedBatch {
                id: next_id,
                created_at: self.created_at,
                insertable: false,
                members: right,
            },
        )
    }
}

/// Algorithm 1 over owned batches, evaluated naively: WMA(B ∪ {p}) from
/// the raw Eq. 2–4 member scan (integer-exact, so decisions match the
/// batcher's O(1) decomposition bit for bit), MEM from Eq. 5, min-WMA
/// ties broken by batch id, threshold Φ compared in f64 exactly like
/// `AdaptiveBatcher::insert`.
#[allow(clippy::too_many_arguments)]
fn insert_owned(
    queue: &mut Vec<OwnedBatch>,
    next_batch_id: &mut u64,
    wma_threshold: f64,
    theta: u64,
    delta: u64,
    max_batch_size: u32,
    req: Request,
    predicted: u32,
    now: f64,
) {
    let mut best: Option<usize> = None;
    let mut best_w = u64::MAX;
    let mut best_id = u64::MAX;
    for (i, b) in queue.iter().enumerate() {
        if !b.insertable {
            continue;
        }
        if max_batch_size > 0 && b.members.len() as u32 >= max_batch_size {
            continue;
        }
        let new_len = b.len().max(req.request_len);
        let new_gen = b.predicted_gen().max(predicted);
        if mem_bytes(b.members.len() as u32 + 1, new_len, new_gen, delta) > theta {
            continue;
        }
        let mut w = wma_gen(req.request_len, predicted, new_len)
            + wma_wait(predicted, new_gen, new_len);
        for (m, p) in &b.members {
            w = w.max(
                wma_gen(m.request_len, *p, new_len) + wma_wait(*p, new_gen, new_len),
            );
        }
        if w < best_w || (w == best_w && b.id < best_id) {
            best_w = w;
            best = Some(i);
            best_id = b.id;
        }
    }
    match best {
        Some(i) if (best_w as f64) < wma_threshold => {
            queue[i].members.push((req, predicted));
        }
        _ => {
            queue.push(OwnedBatch {
                id: *next_batch_id,
                created_at: now,
                insertable: true,
                members: vec![(req, predicted)],
            });
            *next_batch_id += 1;
        }
    }
}

/// The §III-B / §III-D continuous-learning sweeps replicated over owned
/// logs (same periods, thresholds, cursors and call order as
/// `learning::ContinuousLearner`).
struct OwnedLearner {
    cfg: LearningConfig,
    last_pred_sweep: f64,
    last_est_sweep: f64,
    pred_cursor: usize,
    est_cursor: usize,
}

impl OwnedLearner {
    fn new(cfg: LearningConfig) -> OwnedLearner {
        OwnedLearner {
            cfg,
            last_pred_sweep: 0.0,
            last_est_sweep: 0.0,
            pred_cursor: 0,
            est_cursor: 0,
        }
    }

    fn tick(
        &mut self,
        now: f64,
        req_log: &[(Request, u32, f64)],
        batch_log: &[(BatchShape, f64, f64, f64)],
        predictor: &mut GenLenPredictor,
        estimator: &mut ServingTimeEstimator,
    ) {
        if now - self.last_pred_sweep >= self.cfg.predictor_period_s {
            self.last_pred_sweep = now;
            let mut n_bad = 0usize;
            for (req, predicted, _at) in &req_log[self.pred_cursor..] {
                let err = (*predicted as f64 - req.gen_len as f64).abs();
                if err > self.cfg.predictor_err_tokens
                    && err > self.cfg.predictor_err_frac * req.gen_len as f64
                {
                    n_bad += 1;
                    predictor.absorb(req);
                }
            }
            self.pred_cursor = req_log.len();
            if n_bad > 0 {
                predictor.refit();
            }
        }
        if now - self.last_est_sweep >= self.cfg.estimator_period_s {
            self.last_est_sweep = now;
            let mut shapes: Vec<BatchShape> = Vec::new();
            let mut times: Vec<f64> = Vec::new();
            for (shape, _est, actual, _at) in &batch_log[self.est_cursor..] {
                let repredicted = estimator.estimate(shape);
                let err = (repredicted - actual).abs();
                if err > self.cfg.estimator_err_s
                    && err > self.cfg.estimator_err_frac * actual
                {
                    shapes.push(*shape);
                    times.push(*actual);
                }
            }
            self.est_cursor = batch_log.len();
            if !shapes.is_empty() {
                estimator.augment_and_refit(&shapes, &times);
            }
        }
    }
}

enum Event {
    Arrival(usize),
    BatchDone(usize, OwnedBatch, f64, BatchOutcome),
    InstanceReady(usize),
}

/// Run the Magnus-family pipeline carrying owned `Request`s end to end —
/// the pre-refactor allocation profile (clone per arrival, clone per log
/// entry, member rescans per decision).  Behaviour is bit-identical to
/// the compact path; cost is what `BENCH_scale.json` measures against.
pub fn run_magnus_owned(
    cfg: &ServingConfig,
    policy: &MagnusPolicy,
    mut predictor: GenLenPredictor,
    engine: &dyn InferenceEngine,
    trace: &[Request],
) -> SimOutput {
    let wma_threshold = cfg.wma_threshold;
    let theta = (cfg.gpu.theta() as f64 * cfg.mem_margin) as u64;
    let delta = cfg.gpu.delta_bytes_per_token;

    let mut estimator = ServingTimeEstimator::new(cfg.knn_k);
    let mut learner = OwnedLearner::new(cfg.learning.clone());
    let mut metrics = RunMetrics::new();
    let mut pred_errors = Vec::new();
    let mut est_errors = Vec::new();
    // Owned logs: every completion clones its request back out — the
    // second copy of the owned path's per-request tax.
    let mut req_log: Vec<(Request, u32, f64)> = Vec::new();
    let mut batch_log: Vec<(BatchShape, f64, f64, f64)> = Vec::new();

    let mut events: EventQueue<Event> = EventQueue::new();
    for (i, r) in trace.iter().enumerate() {
        events.push(r.arrival, Event::Arrival(i));
    }

    let mut queue: Vec<OwnedBatch> = Vec::new();
    let mut next_batch_id = 0u64;
    let mut idle: VecDeque<usize> = (0..cfg.n_instances).collect();
    let mut served = 0usize;

    while let Some((now, ev)) = events.pop() {
        match ev {
            Event::Arrival(i) => {
                // First copy of the owned tax: the arrival clone.
                let req = trace[i].clone();
                let predicted = predictor.predict(&req);
                pred_errors.push((now, (predicted as f64 - req.gen_len as f64).abs()));
                insert_owned(
                    &mut queue,
                    &mut next_batch_id,
                    wma_threshold,
                    theta,
                    delta,
                    policy.max_batch_size,
                    req,
                    predicted,
                    now,
                );
            }
            Event::BatchDone(inst, batch, est, outcome) => {
                match outcome {
                    BatchOutcome::Completed {
                        serving_time,
                        per_request,
                    } => {
                        served += per_request.len();
                        for ((req, predicted), sr) in batch.members.iter().zip(&per_request) {
                            metrics.record(RequestRecord {
                                request_id: sr.request_id,
                                arrival: req.arrival,
                                finish: now,
                                valid_tokens: sr.valid_tokens,
                                invalid_tokens: sr.invalid_tokens,
                            });
                            req_log.push((req.clone(), *predicted, now));
                        }
                        est_errors.push((now, (est - serving_time).abs()));
                        batch_log.push((batch.true_shape(), est, serving_time, now));
                    }
                    BatchOutcome::Oom { .. } => unreachable!("OOM resolved at dispatch"),
                }
                if policy.use_estimator {
                    learner.tick(now, &req_log, &batch_log, &mut predictor, &mut estimator);
                }
                idle.push_back(inst);
            }
            Event::InstanceReady(inst) => idle.push_back(inst),
        }

        // Dispatch: fresh views + linear-scan select, every round.
        while !idle.is_empty() && !queue.is_empty() {
            let views: Vec<BatchView> = queue
                .iter()
                .map(|b| BatchView {
                    queuing_time: (now - b.min_arrival()).max(0.0),
                    est_serving_time: estimator.estimate(&b.predicted_shape()),
                    created_at: b.created_at,
                    batch_id: b.id,
                })
                .collect();
            let pick = select(policy.sched, &views).unwrap();
            let est = views[pick].est_serving_time;
            let batch = queue.remove(pick);
            let inst = idle.pop_front().unwrap();

            match engine.serve_batch(&batch.to_engine_batch()) {
                BatchOutcome::Oom {
                    at_iteration: _,
                    wasted_time,
                } => {
                    metrics.record_oom();
                    let nid = next_batch_id;
                    next_batch_id += 1;
                    let (l, r) = batch.split(nid);
                    queue.push(l);
                    queue.push(r);
                    events.push(
                        now + wasted_time + OOM_RELOAD_S,
                        Event::InstanceReady(inst),
                    );
                }
                done @ BatchOutcome::Completed { .. } => {
                    let serving_time = match &done {
                        BatchOutcome::Completed { serving_time, .. } => *serving_time,
                        _ => unreachable!(),
                    };
                    events.push(now + serving_time, Event::BatchDone(inst, batch, est, done));
                }
            }
        }
    }

    debug_assert_eq!(served, trace.len(), "all requests must complete");

    // Materialise the logs in the shared output form (outside any timed
    // path; counts/telemetry feed the golden comparison).
    let db = LogDb::new();
    for (req, predicted, at) in &req_log {
        db.log_request(RequestLog {
            meta: RequestMeta::detached(req),
            predicted_gen_len: *predicted,
            actual_gen_len: req.gen_len,
            at: *at,
        });
    }
    for (shape, est, actual, at) in &batch_log {
        db.log_batch(BatchLog {
            shape: *shape,
            estimated_time: *est,
            actual_time: *actual,
            at: *at,
        });
    }
    SimOutput {
        metrics,
        db,
        pred_errors,
        est_errors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::cost::CostModelEngine;
    use crate::predictor::Variant;
    use crate::sim::run_magnus;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::{generate_trace, LlmProfile, TraceSpec};

    #[test]
    fn owned_reference_completes_and_matches_compact_counts() {
        let cfg = ServingConfig::default();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 80, 5, 1024, 30);
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let mut p2 = GenLenPredictor::new(Variant::Usin, &cfg);
        p2.train(&split.train);
        let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
        let trace = generate_trace(&TraceSpec {
            rate: 5.0,
            n_requests: 180,
            seed: 3,
            ..Default::default()
        });
        let owned = run_magnus_owned(&cfg, &MagnusPolicy::magnus(), p, &engine, &trace);
        let compact = run_magnus(&cfg, &MagnusPolicy::magnus(), p2, &engine, &trace);
        assert_eq!(owned.metrics.records.len(), 180);
        assert_eq!(owned.db.n_requests(), compact.db.n_requests());
        assert_eq!(owned.db.n_batches(), compact.db.n_batches());
        for (x, y) in owned.metrics.records.iter().zip(&compact.metrics.records) {
            assert_eq!(x.request_id, y.request_id);
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
        }
    }
}
