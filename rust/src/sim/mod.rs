//! Discrete-event simulator: runs every serving policy of §IV over the
//! calibrated cost-model engine at full (7-instance, V100-scale) size, so
//! the paper's figures regenerate in seconds.  The simulator reuses the
//! *same* policy objects (batcher, scheduler, estimator, learner) as the
//! live PJRT server — only the engine and the clock differ.

pub mod ccb;
pub mod events;
pub mod magnus;
pub mod reference;
pub mod vanilla;

use crate::config::ServingConfig;
use crate::engine::cost::CostModelEngine;
use crate::engine::quantized::QuantizedEngine;
use crate::metrics::Summary;
use crate::predictor::{GenLenPredictor, Variant};
use crate::workload::dataset::build_predictor_split;
use crate::workload::{LlmProfile, Request, TraceStore};

pub use events::EventQueue;
pub use magnus::{
    run_magnus, run_magnus_store, run_magnus_store_faulted, run_magnus_store_with,
    run_magnus_with, DispatchMode, MagnusPolicy, SimOutput,
};
pub use reference::run_magnus_owned;

/// Post-OOM reload penalty (empty GPU memory + reload LLM, §III-F),
/// shared by the simulator backends.
pub(crate) const OOM_RELOAD_S: f64 = 20.0;

/// Every serving policy of the evaluation (§IV-B baselines + §IV-C
/// ablations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Vanilla scheduling: FCFS, fixed β from Eq. (1).
    Vs,
    /// VS + 4-bit quantization, fixed β = 10.
    Vsq,
    /// Conservative continuous batching, parallel limit 7.
    Ccb,
    /// VS + generation-length prediction + WMA batching (fixed β).
    Glp,
    /// GLP + adaptive batch sizes.
    Abp,
    /// ABP + serving-time estimation + HRRN — the full system.
    Magnus,
}

impl Policy {
    pub const ALL: [Policy; 6] = [
        Policy::Vs,
        Policy::Vsq,
        Policy::Ccb,
        Policy::Glp,
        Policy::Abp,
        Policy::Magnus,
    ];

    pub const BASELINES: [Policy; 4] = [Policy::Vs, Policy::Vsq, Policy::Ccb, Policy::Magnus];
    pub const ABLATION: [Policy; 4] = [Policy::Vs, Policy::Glp, Policy::Abp, Policy::Magnus];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Vs => "VS",
            Policy::Vsq => "VSQ",
            Policy::Ccb => "CCB",
            Policy::Glp => "GLP",
            Policy::Abp => "ABP",
            Policy::Magnus => "Magnus",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        Policy::ALL
            .iter()
            .copied()
            .find(|p| p.name().eq_ignore_ascii_case(s))
    }
}

/// Train the full (USIN) predictor on the paper's held-out split.
pub fn trained_predictor(cfg: &ServingConfig, n_train: usize) -> GenLenPredictor {
    let split = build_predictor_split(
        LlmProfile::ChatGlm6B,
        n_train,
        1,
        cfg.gpu.g_max,
        cfg.seed ^ 0x5052_4544,
    );
    let mut p = GenLenPredictor::new(Variant::Usin, cfg);
    p.train(&split.train);
    p
}

/// Run `policy` over `trace`, returning the full sim output (metrics +
/// logs).  `predictor_train` is the per-task training-set size for the
/// prediction-based policies (the paper trains on 2 500 held-out requests
/// per task; accuracy saturates well before, so the figure drivers default
/// to a few hundred for speed).
pub fn run_policy(
    cfg: &ServingConfig,
    policy: Policy,
    trace: &[Request],
    predictor_train: usize,
) -> SimOutput {
    // One interning pass, then the zero-copy core — the policy arms live
    // only in `run_policy_store`, so the two entry points cannot drift.
    run_policy_store(
        cfg,
        policy,
        &TraceStore::from_requests(trace),
        predictor_train,
    )
}

/// [`run_policy`] over an interned [`TraceStore`] — the zero-copy entry
/// point for every policy (no owned `Vec<Request>` is ever materialised).
pub fn run_policy_store(
    cfg: &ServingConfig,
    policy: Policy,
    store: &TraceStore,
    predictor_train: usize,
) -> SimOutput {
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    match policy {
        Policy::Vs => wrap(vanilla::run_vanilla_store(
            cfg,
            cfg.gpu.vanilla_batch_size(),
            &engine,
            store,
        )),
        Policy::Vsq => {
            let q = QuantizedEngine::new(
                CostModelEngine::new(cfg.cost.clone(), &cfg.gpu),
                cfg.quant.clone(),
            );
            wrap(vanilla::run_vanilla_store(
                cfg,
                cfg.quant.batch_size,
                &q,
                store,
            ))
        }
        Policy::Ccb => wrap(ccb::run_ccb_store(
            cfg,
            cfg.gpu.vanilla_batch_size(),
            &engine,
            store,
        )),
        Policy::Glp => run_magnus_store(
            cfg,
            &MagnusPolicy::glp(cfg.gpu.vanilla_batch_size()),
            trained_predictor(cfg, predictor_train),
            &engine,
            store,
        ),
        Policy::Abp => run_magnus_store(
            cfg,
            &MagnusPolicy::abp(),
            trained_predictor(cfg, predictor_train),
            &engine,
            store,
        ),
        Policy::Magnus => run_magnus_store(
            cfg,
            &MagnusPolicy::magnus(),
            trained_predictor(cfg, predictor_train),
            &engine,
            store,
        ),
    }
}

/// [`run_policy_store`] under a [`FaultPlan`](crate::faults::FaultPlan)
/// (ISSUE 6 chaos axis): the
/// Magnus-family arms run the faulted core; the non-predictive baselines
/// (VS/VSQ/CCB) have no supervised dispatch loop to inject into, so
/// requesting them with a non-noop plan is an error rather than a
/// silently fault-free run.
pub fn run_policy_store_faulted(
    cfg: &ServingConfig,
    policy: Policy,
    store: &TraceStore,
    predictor_train: usize,
    plan: &crate::faults::FaultPlan,
) -> anyhow::Result<SimOutput> {
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let magnus_policy = match policy {
        Policy::Glp => MagnusPolicy::glp(cfg.gpu.vanilla_batch_size()),
        Policy::Abp => MagnusPolicy::abp(),
        Policy::Magnus => MagnusPolicy::magnus(),
        other => {
            if plan.is_noop() {
                return Ok(run_policy_store(cfg, policy, store, predictor_train));
            }
            anyhow::bail!(
                "--fault-plan supports GLP/ABP/Magnus, not {}",
                other.name()
            );
        }
    };
    Ok(run_magnus_store_faulted(
        cfg,
        &magnus_policy,
        trained_predictor(cfg, predictor_train),
        &engine,
        store,
        DispatchMode::Indexed,
        plan,
    ))
}

fn wrap(metrics: crate::metrics::RunMetrics) -> SimOutput {
    SimOutput {
        metrics,
        db: crate::logdb::LogDb::new(),
        pred_errors: Vec::new(),
        est_errors: Vec::new(),
    }
}

/// Convenience: summary only.
pub fn run_policy_summary(
    cfg: &ServingConfig,
    policy: Policy,
    trace: &[Request],
    predictor_train: usize,
) -> Summary {
    run_policy(cfg, policy, trace, predictor_train)
        .metrics
        .summarise()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate_trace, TraceSpec};

    /// The paper's headline orderings (Fig. 10/11) at a moderate load.
    /// This is the core shape-reproduction test of the whole simulator.
    #[test]
    fn fig10_11_orderings_hold() {
        let cfg = ServingConfig::default();
        // Heavy overload: every policy saturated, summaries measure
        // capacity — the regime of the paper's Fig. 10/11 claims.
        let trace = generate_trace(&TraceSpec {
            rate: 10.0,
            n_requests: 600,
            seed: 99,
            ..Default::default()
        });
        let s: Vec<Summary> = Policy::BASELINES
            .iter()
            .map(|p| run_policy_summary(&cfg, *p, &trace, 200))
            .collect();
        let (vs, vsq, ccb, magnus) = (&s[0], &s[1], &s[2], &s[3]);

        // Request throughput: Magnus > CCB > VS > VSQ  (Fig. 11a)
        assert!(magnus.request_throughput > ccb.request_throughput,
            "magnus {:.3} !> ccb {:.3}", magnus.request_throughput, ccb.request_throughput);
        assert!(ccb.request_throughput > vs.request_throughput,
            "ccb {:.3} !> vs {:.3}", ccb.request_throughput, vs.request_throughput);
        assert!(vs.request_throughput > vsq.request_throughput,
            "vs {:.3} !> vsq {:.3}", vs.request_throughput, vsq.request_throughput);

        // Mean response time: Magnus < CCB < VS < VSQ  (Fig. 11b)
        assert!(magnus.mean_response_time < ccb.mean_response_time);
        assert!(ccb.mean_response_time < vs.mean_response_time);
        assert!(vs.mean_response_time < vsq.mean_response_time);

        // Valid-token throughput: Magnus > CCB  (Fig. 10b: CCB second)
        assert!(magnus.valid_token_throughput > ccb.valid_token_throughput);
        // CCB has the smallest total token throughput among baselines (Fig. 10a)
        assert!(ccb.token_throughput < vs.token_throughput);
    }

    #[test]
    fn ablation_ordering_holds() {
        let cfg = ServingConfig::default();
        let trace = generate_trace(&TraceSpec {
            rate: 10.0,
            n_requests: 500,
            seed: 123,
            ..Default::default()
        });
        let vs = run_policy_summary(&cfg, Policy::Vs, &trace, 200);
        let glp = run_policy_summary(&cfg, Policy::Glp, &trace, 200);
        let abp = run_policy_summary(&cfg, Policy::Abp, &trace, 200);
        let magnus = run_policy_summary(&cfg, Policy::Magnus, &trace, 200);

        // Fig. 13: VS < GLP < ABP ≈ Magnus on request throughput.
        assert!(glp.request_throughput > vs.request_throughput,
            "glp {:.3} !> vs {:.3}", glp.request_throughput, vs.request_throughput);
        assert!(abp.request_throughput > glp.request_throughput,
            "abp {:.3} !> glp {:.3}", abp.request_throughput, glp.request_throughput);
        assert!(magnus.request_throughput > abp.request_throughput * 0.9);
        // HRRN reduces response time without hurting throughput.
        assert!(magnus.mean_response_time <= abp.mean_response_time * 1.05);
    }

    /// The store entry point wires every policy arm exactly like the
    /// owned entry point (zero-copy changes representation, not
    /// behaviour — bitwise on the summary metrics).
    #[test]
    fn run_policy_store_matches_run_policy_for_every_policy() {
        let cfg = ServingConfig::default();
        let spec = TraceSpec {
            rate: 3.0,
            n_requests: 80,
            seed: 55,
            ..Default::default()
        };
        let trace = generate_trace(&spec);
        let store = TraceStore::generate(&spec);
        for policy in Policy::ALL {
            let a = run_policy(&cfg, policy, &trace, 20).metrics.summarise();
            let b = run_policy_store(&cfg, policy, &store, 20)
                .metrics
                .summarise();
            assert_eq!(a.n_requests, b.n_requests, "{}", policy.name());
            assert_eq!(
                a.request_throughput.to_bits(),
                b.request_throughput.to_bits(),
                "{}",
                policy.name()
            );
            assert_eq!(
                a.mean_response_time.to_bits(),
                b.mean_response_time.to_bits(),
                "{}",
                policy.name()
            );
            assert_eq!(
                a.token_throughput.to_bits(),
                b.token_throughput.to_bits(),
                "{}",
                policy.name()
            );
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()), Some(p));
        }
        assert_eq!(Policy::parse("nope"), None);
    }
}
