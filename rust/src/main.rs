//! `magnus` — the serving-system CLI (leader entrypoint).
//!
//! Subcommands:
//!   serve      replay a workload through the LIVE cluster (real PJRT
//!              compute via the AOT artifacts) under a chosen policy
//!   sim        run a policy over a synthetic workload on the calibrated
//!              cost-model engine (V100-scale, fast)
//!   gen-trace  write a workload trace as JSON
//!   eval-pred  train + evaluate the four predictor variants
//!
//! Examples:
//!   magnus sim --policy magnus --rate 10 --requests 800
//!   magnus serve --workers 2 --requests 20 --time-scale 20
//!   magnus gen-trace --rate 5 --requests 1000 --out trace.json
//!   magnus eval-pred --train 600 --test 200

use magnus::config::ServingConfig;
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::sim::{run_policy, Policy};
use magnus::util::cli::Args;
use magnus::util::stats::rmse;
use magnus::workload::dataset::build_predictor_split;
use magnus::workload::{generate_trace, trace_to_json, LlmProfile, TraceSpec};

const USAGE: &str = "magnus <serve|sim|gen-trace|eval-pred> [options]
  common:    --config <file.json>  --seed N
  sim:       --policy VS|VSQ|CCB|GLP|ABP|Magnus  --rate R --requests N --train N
  serve:     --policy magnus|vanilla --workers N --rate R --requests N
             --time-scale S --g-max N --l-cap N [--trace file.json]
  gen-trace: --rate R --requests N --out file.json
  eval-pred: --train N --test N";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse_env(&["help", "warm-up"]).map_err(anyhow::Error::msg)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let mut cfg = ServingConfig::load(args.get("config"))?;
    if let Some(seed) = args.get("seed") {
        cfg.seed = seed.parse().unwrap_or(cfg.seed);
    }

    match cmd {
        "sim" => {
            let policy = Policy::parse(args.get_or("policy", "Magnus"))
                .ok_or_else(|| anyhow::anyhow!("unknown policy"))?;
            let trace = generate_trace(&TraceSpec {
                rate: args.get_f64("rate", 10.0),
                n_requests: args.get_usize("requests", 800),
                seed: cfg.seed,
                ..Default::default()
            });
            let out = run_policy(&cfg, policy, &trace, args.get_usize("train", 300));
            let s = out.metrics.summarise();
            println!(
                "{}: {} requests | thr {:.3} req/s | mean RT {:.1}s | p95 RT {:.1}s | \
                 tokens {:.1}/s (valid {:.1}/s) | OOM {}",
                policy.name(),
                s.n_requests,
                s.request_throughput,
                s.mean_response_time,
                s.p95_response_time,
                s.token_throughput,
                s.valid_token_throughput,
                s.oom_events
            );
        }
        "serve" => cmd_serve(&args, &mut cfg)?,
        "gen-trace" => {
            let trace = generate_trace(&TraceSpec {
                rate: args.get_f64("rate", 5.0),
                n_requests: args.get_usize("requests", 1000),
                g_max: args.get_u64("g-max", 1024) as u32,
                l_cap: args.get_u64("l-cap", 0) as u32,
                seed: cfg.seed,
                ..Default::default()
            });
            let json = trace_to_json(&trace).to_string_pretty();
            match args.get("out") {
                Some(path) => {
                    std::fs::write(path, json)?;
                    println!("wrote {} requests to {path}", trace.len());
                }
                None => println!("{json}"),
            }
        }
        "eval-pred" => {
            let split = build_predictor_split(
                LlmProfile::ChatGlm6B,
                args.get_usize("train", 600),
                args.get_usize("test", 200),
                cfg.gpu.g_max,
                cfg.seed,
            );
            for v in Variant::ALL {
                let mut p = GenLenPredictor::new(v, &cfg);
                p.train(&split.train);
                let pred: Vec<f64> =
                    split.test.iter().map(|r| p.predict(r) as f64).collect();
                let act: Vec<f64> =
                    split.test.iter().map(|r| r.gen_len as f64).collect();
                println!("{:5}  RMSE {:.2}", v.name(), rmse(&pred, &act));
            }
        }
        _ => println!("{USAGE}"),
    }
    Ok(())
}

/// Replay a workload through the LIVE cluster (real PJRT compute).
#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args, cfg: &mut ServingConfig) -> anyhow::Result<()> {
    use magnus::server::{serve_trace, LivePolicy, ServeOptions};
    use magnus::sim::MagnusPolicy;
    use magnus::workload::trace_from_json;

    let g_max = args.get_u64("g-max", 24) as u32;
    let l_cap = args.get_u64("l-cap", 40) as u32;
    cfg.gpu.g_max = g_max;
    let trace = match args.get("trace") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            let j = magnus::util::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            trace_from_json(&j)?
        }
        None => generate_trace(&TraceSpec {
            rate: args.get_f64("rate", 2.0),
            n_requests: args.get_usize("requests", 20),
            g_max,
            l_cap,
            seed: cfg.seed,
            ..Default::default()
        }),
    };
    let policy_name = args.get_or("policy", "magnus").to_ascii_lowercase();
    let (policy, predictor) = match policy_name.as_str() {
        "vanilla" | "vs" => (
            LivePolicy::Vanilla {
                fixed_batch: args.get_u64("fixed-batch", 4) as u32,
            },
            None,
        ),
        _ => {
            let split =
                build_predictor_split(LlmProfile::ChatGlm6B, 150, 5, g_max, cfg.seed);
            let mut p = GenLenPredictor::new(Variant::Usin, cfg);
            p.train(&split.train);
            (LivePolicy::Magnus(MagnusPolicy::magnus()), Some(p))
        }
    };
    let metrics = serve_trace(
        cfg,
        &ServeOptions {
            artifacts_dir: args.get_or("artifacts", "artifacts").to_string(),
            n_workers: args.get_usize("workers", 2),
            time_scale: args.get_f64("time-scale", 10.0),
            warm_up: args.flag("warm-up"),
        },
        policy,
        predictor,
        &trace,
    )?;
    let s = metrics.summarise();
    println!(
        "live {}: {} requests | thr {:.3} req/s | mean RT {:.2}s | p95 RT {:.2}s \
         (replayed seconds)",
        policy_name, s.n_requests, s.request_throughput,
        s.mean_response_time, s.p95_response_time
    );
    Ok(())
}

/// Without the `pjrt` feature the live path is compiled out entirely.
#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args, _cfg: &mut ServingConfig) -> anyhow::Result<()> {
    anyhow::bail!(
        "`serve` needs the live PJRT stack; rebuild with `--features pjrt` \
         (requires the vendored xla crate, see rust/Cargo.toml)"
    )
}
