//! `magnus` — the serving-system CLI (leader entrypoint).
//!
//! Subcommands:
//!   serve      replay a workload through the LIVE cluster (real PJRT
//!              compute via the AOT artifacts) under a chosen policy
//!   serve-sim  replay a workload through the supervised cluster over the
//!              cost-model backend (no artifacts needed) — accepts a
//!              deterministic fault plan for chaos drills
//!   serve-cluster  route a workload over M engine instances by predicted
//!              generation length (rr|jspq|p2c|band|shard), with heartbeat
//!              health checks and prediction-aware failover; the default
//!              discrete-event run is deterministic and seed-replayable,
//!              `--live` drives M supervised cores over real threads; a
//!              sharded trace directory maps one shard per instance
//!   sim        run a policy over a synthetic workload on the calibrated
//!              cost-model engine (V100-scale, fast)
//!   gen-trace  write a workload trace (JSON, the binary format when the
//!              output path ends in .mtr, or a sharded binary trace +
//!              manifest with `--shards N --out dir`)
//!   pack-trace convert a JSON trace to the mmap-able binary format
//!   eval-pred  train + evaluate the four predictor variants
//!   serve-edge run the HTTP front door (predicted-length admission,
//!              deadlines, /metrics) over the cost-model cluster
//!   load-gen   open-loop Poisson/bursty load against a live edge
//!
//! Examples:
//!   magnus sim --policy magnus --rate 10 --requests 800
//!   magnus sim --policy magnus --fault-plan "seed=7,crash=0.1,oom=0..50@0.2"
//!   magnus serve --workers 2 --requests 20 --time-scale 20
//!   magnus serve-sim --workers 2 --requests 100 --fault-plan plan.json
//!   magnus serve-cluster --instances 4 --route jspq --rate 16 --requests 600 \
//!       --fault-plan "ikill=1:40..90,islow=2:20..80@6"
//!   magnus serve-edge --addr 127.0.0.1:8080 --duration 30 --token-budget 4096
//!   magnus load-gen --addr 127.0.0.1:8080 --rps 200 --requests 2000 \
//!       --burst 2@4 --fault-plan "seed=3,conndrop=0.05,slowclient=0.05@0.2"
//!   magnus gen-trace --rate 5 --requests 1000 --out trace.json
//!   magnus gen-trace --rate 5 --requests 1000000 --out trace.mtr
//!   magnus gen-trace --rate 8 --requests 10000000 --shards 8 --out traces/big
//!   magnus serve-cluster --trace traces/big --instances 8 --route shard
//!   magnus pack-trace --in trace.json --out trace.mtr
//!   magnus eval-pred --train 600 --test 200

use magnus::config::ServingConfig;
use magnus::faults::FaultPlan;
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::sim::{run_policy, run_policy_store_faulted, Policy};
use magnus::util::cli::Args;
use magnus::util::stats::rmse;
use magnus::workload::dataset::build_predictor_split;
use magnus::workload::{
    generate_trace, open_any, write_sharded, LlmProfile, LoadedTrace, TraceSpec, TraceStore,
};

const USAGE: &str = "magnus <serve|serve-sim|serve-cluster|serve-edge|load-gen|sim|gen-trace|pack-trace|eval-pred> [options]
  common:    --config <file.json>  --seed N
             --trace accepts a JSON trace, a binary .mtr trace, a shard
             manifest.json, or a sharded-trace directory — detected by
             content (magic bytes / JSON shape), never by extension
  sim:       --policy VS|VSQ|CCB|GLP|ABP|Magnus  --rate R --requests N --train N
             [--fault-plan file.json|spec]
  serve:     --policy magnus|vanilla --workers N --rate R --requests N
             --time-scale S --g-max N --l-cap N [--trace file|dir]
             [--fault-plan file.json|spec]
  serve-sim: --policy magnus|vanilla --workers N --rate R --requests N
             --time-scale S --g-max N --l-cap N [--fault-plan file.json|spec]
  serve-cluster: --instances M --route rr|jspq|p2c|band|shard --rate R --requests N
             --hb-interval S --suspect-after N --steal-threshold TOKENS
             [--trace file|dir  (a sharded trace needs --instances == shards)]
             [--live --workers N --time-scale S] [--fault-plan file.json|spec]
  serve-edge: --addr H:P --workers N --time-scale S --duration SECS
             --queue-cap N --token-budget T --rps-limit R --deadline SECS
             [--trace file|dir] [--fault-plan file.json|spec]
  load-gen:  --addr H:P --rps R --requests N --conns N --trace-len N
             [--burst PERIOD@FACTOR] [--deadline-ms MS]
             [--fault-plan \"seed=N,conndrop=P,slowclient=P@DELAY\"]
  gen-trace: --rate R --requests N --out file.json|file.mtr (binary, mmap-able)
             [--shards N --out dir  (N shard files + manifest.json)]
  pack-trace: --in trace.json [--out trace.mtr]
  eval-pred: --train N --test N
  fault-plan spec: seed=N,crash=P,err=P,stall=A..B@F,oom=A..B@P,guard,
             predoff=A..B[:heuristic|:max],noise=BIAS@JITTER,
             retries=N,restarts=N,backoff=S,conndrop=P,slowclient=P@DELAY,
             ikill=I:A..B,islow=I:A..B@F,ipart=I:A..B (instance axes)";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse_env(&["help", "warm-up", "live"]).map_err(anyhow::Error::msg)?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let mut cfg = ServingConfig::load(args.get("config"))?;
    if let Some(seed) = args.get("seed") {
        cfg.seed = seed.parse().unwrap_or(cfg.seed);
    }

    match cmd {
        "sim" => {
            let policy = Policy::parse(args.get_or("policy", "Magnus"))
                .ok_or_else(|| anyhow::anyhow!("unknown policy"))?;
            let trace = generate_trace(&TraceSpec {
                rate: args.get_f64("rate", 10.0),
                n_requests: args.get_usize("requests", 800),
                seed: cfg.seed,
                ..Default::default()
            });
            let train = args.get_usize("train", 300);
            let out = match args.get("fault-plan") {
                Some(spec) => {
                    let plan = FaultPlan::load(spec)?;
                    let store = TraceStore::from_requests(&trace);
                    run_policy_store_faulted(&cfg, policy, &store, train, &plan)?
                }
                None => run_policy(&cfg, policy, &trace, train),
            };
            let s = out.metrics.summarise();
            println!(
                "{}: {} requests | thr {:.3} req/s | RT mean {:.1}s p50 {:.1}s p95 {:.1}s \
                 p99 {:.1}s | tokens {:.1}/s (valid {:.1}/s) | OOM {}",
                policy.name(),
                s.n_requests,
                s.request_throughput,
                s.mean_response_time,
                s.p50_response_time,
                s.p95_response_time,
                s.p99_response_time,
                s.token_throughput,
                s.valid_token_throughput,
                s.oom_events
            );
            if args.get("fault-plan").is_some() {
                println!(
                    "  faults: shed {} | retries {} | restarts {} | fallback preds {} | \
                     injected {}",
                    s.shed_requests,
                    s.retries,
                    s.worker_restarts,
                    s.fallback_predictions,
                    out.metrics.injected_faults
                );
            }
        }
        "serve" => cmd_serve(&args, &mut cfg)?,
        "serve-sim" => cmd_serve_sim(&args, &mut cfg)?,
        "serve-cluster" => cmd_serve_cluster(&args, &mut cfg)?,
        "serve-edge" => cmd_serve_edge(&args, &mut cfg)?,
        "load-gen" => cmd_load_gen(&args)?,
        "gen-trace" => {
            let spec = TraceSpec {
                rate: args.get_f64("rate", 5.0),
                n_requests: args.get_usize("requests", 1000),
                g_max: args.get_u64("g-max", 1024) as u32,
                l_cap: args.get_u64("l-cap", 0) as u32,
                seed: cfg.seed,
                ..Default::default()
            };
            let shards = args.get_usize("shards", 1);
            if shards > 1 {
                // Sharded generation streams one shard at a time — peak
                // memory is one shard, which is what makes 10⁷–10⁸
                // request traces writable at all.
                let dir = args.get("out").ok_or_else(|| {
                    anyhow::anyhow!("gen-trace --shards needs --out <dir> for the shard files")
                })?;
                let manifest = write_sharded(&spec, shards, std::path::Path::new(dir))?;
                println!(
                    "wrote {} requests across {shards} shards under {dir} (manifest {})",
                    spec.n_requests,
                    manifest.display()
                );
                return Ok(());
            }
            // Streaming generation: the trace lands in a TraceStore arena
            // (never a Vec<Request>), and serialises to either schema —
            // the store's JSON is byte-identical to the owned route's.
            // Output format follows the extension (a write has no
            // content to sniff; reads are sniffed — see `open_any`).
            let store = TraceStore::generate(&spec);
            match args.get("out") {
                Some(path) if path.ends_with(".mtr") => {
                    store.write_file(path)?;
                    println!(
                        "wrote {} requests (binary trace, {} arena bytes) to {path}",
                        store.len(),
                        store.arena_bytes()
                    );
                }
                Some(path) => {
                    std::fs::write(path, store.to_json().to_string_pretty())?;
                    println!("wrote {} requests to {path}", store.len());
                }
                None => println!("{}", store.to_json().to_string_pretty()),
            }
        }
        "pack-trace" => {
            let input = args
                .get("in")
                .ok_or_else(|| anyhow::anyhow!("pack-trace needs --in <trace.json>"))?;
            let out = args.get("out").map(str::to_string).unwrap_or_else(|| {
                format!("{}.mtr", input.strip_suffix(".json").unwrap_or(input))
            });
            // Content-sniffed load: a binary input repacks byte-exactly,
            // a JSON trace interns; a shard manifest is refused with a
            // hint rather than silently flattened.
            let store =
                open_any(std::path::Path::new(input))?.require_single("pack-trace")?;
            store.write_file(&out)?;
            println!(
                "packed {} requests: {input} ({} bytes) -> {out} ({} bytes; \
                 opens O(1) via mmap)",
                store.len(),
                std::fs::metadata(input)?.len(),
                std::fs::metadata(&out)?.len()
            );
        }
        "eval-pred" => {
            let split = build_predictor_split(
                LlmProfile::ChatGlm6B,
                args.get_usize("train", 600),
                args.get_usize("test", 200),
                cfg.gpu.g_max,
                cfg.seed,
            );
            for v in Variant::ALL {
                let mut p = GenLenPredictor::new(v, &cfg);
                p.train(&split.train);
                let pred: Vec<f64> =
                    split.test.iter().map(|r| p.predict(r) as f64).collect();
                let act: Vec<f64> =
                    split.test.iter().map(|r| r.gen_len as f64).collect();
                println!("{:5}  RMSE {:.2}", v.name(), rmse(&pred, &act));
            }
        }
        _ => println!("{USAGE}"),
    }
    Ok(())
}

/// Load a single-store `--trace` argument for `what` by content
/// sniffing (`open_any`), then apply an explicit `--requests N`: a
/// shorter prefix is an O(1) view into the open trace, and a count
/// beyond the trace clamps with a warning — the CLI boundary never
/// reaches `TraceStore::meta` with an out-of-range index.
fn load_single_trace(
    path: &str,
    what: &str,
    requests: Option<usize>,
) -> anyhow::Result<TraceStore> {
    let store = open_any(std::path::Path::new(path))?.require_single(what)?;
    Ok(match requests {
        Some(n) if n < store.len() => store.prefix(n),
        Some(n) if n > store.len() => {
            eprintln!(
                "warning: --requests {n} exceeds the {} requests in {path}; replaying all of them",
                store.len()
            );
            store
        }
        _ => store,
    })
}

/// The explicit `--requests` value, if one was passed (defaults must not
/// truncate a loaded trace).
fn explicit_requests(args: &Args) -> Option<usize> {
    args.get("requests").and_then(|s| s.parse().ok())
}

/// Replay a workload through the LIVE cluster (real PJRT compute).
#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args, cfg: &mut ServingConfig) -> anyhow::Result<()> {
    use std::sync::Arc;

    use magnus::server::{serve_trace_store, LivePolicy, ServeOptions};
    use magnus::sim::MagnusPolicy;

    let g_max = args.get_u64("g-max", 24) as u32;
    let l_cap = args.get_u64("l-cap", 40) as u32;
    cfg.gpu.g_max = g_max;
    // Both sources produce the same Arc<TraceStore> the workers share; a
    // binary trace maps read-only (open is O(1), and several server
    // processes replaying one trace share the mapping).  Format is
    // sniffed from content, never the extension.
    let store = match args.get("trace") {
        Some(path) => Arc::new(load_single_trace(path, "serve", explicit_requests(args))?),
        None => Arc::new(TraceStore::generate(&TraceSpec {
            rate: args.get_f64("rate", 2.0),
            n_requests: args.get_usize("requests", 20),
            g_max,
            l_cap,
            seed: cfg.seed,
            ..Default::default()
        })),
    };
    let policy_name = args.get_or("policy", "magnus").to_ascii_lowercase();
    let (policy, predictor) = match policy_name.as_str() {
        "vanilla" | "vs" => (
            LivePolicy::Vanilla {
                fixed_batch: args.get_u64("fixed-batch", 4) as u32,
            },
            None,
        ),
        _ => {
            let split =
                build_predictor_split(LlmProfile::ChatGlm6B, 150, 5, g_max, cfg.seed);
            let mut p = GenLenPredictor::new(Variant::Usin, cfg);
            p.train(&split.train);
            (LivePolicy::Magnus(MagnusPolicy::magnus()), Some(p))
        }
    };
    let metrics = serve_trace_store(
        cfg,
        &ServeOptions {
            artifacts_dir: args.get_or("artifacts", "artifacts").to_string(),
            n_workers: args.get_usize("workers", 2),
            time_scale: args.get_f64("time-scale", 10.0),
            warm_up: args.flag("warm-up"),
            fault_plan: match args.get("fault-plan") {
                Some(spec) => FaultPlan::load(spec)?,
                None => FaultPlan::none(),
            },
        },
        policy,
        predictor,
        store,
    )?;
    let s = metrics.summarise();
    println!(
        "live {}: {} requests | thr {:.3} req/s | RT mean {:.2}s p50 {:.2}s p95 {:.2}s \
         p99 {:.2}s (replayed seconds)",
        policy_name, s.n_requests, s.request_throughput,
        s.mean_response_time, s.p50_response_time, s.p95_response_time,
        s.p99_response_time
    );
    Ok(())
}

/// Without the `pjrt` feature the live path is compiled out entirely.
#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args, _cfg: &mut ServingConfig) -> anyhow::Result<()> {
    anyhow::bail!(
        "`serve` needs the live PJRT stack; rebuild with `--features pjrt` \
         (requires the vendored xla crate, see rust/Cargo.toml) — or use \
         `serve-sim` for the cost-model backend"
    )
}

/// Replay a workload through the supervised cluster over the cost-model
/// backend: the same leader/worker machinery as `serve` (threads,
/// channels, wall clock, supervised restarts) with analytic serving
/// times.  No artifacts needed; honours `--fault-plan`.
fn cmd_serve_sim(args: &Args, cfg: &mut ServingConfig) -> anyhow::Result<()> {
    use std::sync::Arc;

    use magnus::server::{serve_trace_store_sim, LivePolicy, ServeOptions};
    use magnus::sim::MagnusPolicy;

    let g_max = args.get_u64("g-max", 64) as u32;
    let l_cap = args.get_u64("l-cap", 80) as u32;
    cfg.gpu.g_max = g_max;
    let store = Arc::new(TraceStore::generate(&TraceSpec {
        rate: args.get_f64("rate", 5.0),
        n_requests: args.get_usize("requests", 100),
        g_max,
        l_cap,
        seed: cfg.seed,
        ..Default::default()
    }));
    let plan = match args.get("fault-plan") {
        Some(spec) => FaultPlan::load(spec)?,
        None => FaultPlan::none(),
    };
    let policy_name = args.get_or("policy", "magnus").to_ascii_lowercase();
    let (policy, predictor) = match policy_name.as_str() {
        "vanilla" | "vs" => (
            LivePolicy::Vanilla {
                fixed_batch: args.get_u64("fixed-batch", 4) as u32,
            },
            None,
        ),
        _ => {
            let split =
                build_predictor_split(LlmProfile::ChatGlm6B, 150, 5, g_max, cfg.seed);
            let mut p = GenLenPredictor::new(Variant::Usin, cfg);
            p.train(&split.train);
            (LivePolicy::Magnus(MagnusPolicy::magnus()), Some(p))
        }
    };
    let metrics = serve_trace_store_sim(
        cfg,
        &ServeOptions {
            n_workers: args.get_usize("workers", 2),
            time_scale: args.get_f64("time-scale", 50.0),
            fault_plan: plan,
            ..Default::default()
        },
        policy,
        predictor,
        store,
    )?;
    let s = metrics.summarise();
    println!(
        "serve-sim {}: {} served, {} shed | thr {:.3} req/s | RT mean {:.2}s p50 {:.2}s \
         p95 {:.2}s p99 {:.2}s | retries {} | restarts {} | fallback preds {} \
         (replayed seconds)",
        policy_name,
        s.n_requests,
        s.shed_requests,
        s.request_throughput,
        s.mean_response_time,
        s.p50_response_time,
        s.p95_response_time,
        s.p99_response_time,
        s.retries,
        s.worker_restarts,
        s.fallback_predictions
    );
    Ok(())
}

/// Route a workload over M logical engine instances by predicted
/// generation length, with heartbeat health checks, failover, and work
/// stealing.  Default is the deterministic discrete-event path; `--live`
/// drives M supervised cost-model cores over real threads.
fn cmd_serve_cluster(args: &Args, cfg: &mut ServingConfig) -> anyhow::Result<()> {
    use magnus::cluster::{parse_route_policy, ClusterOptions, ROUTE_POLICY_NAMES};
    use magnus::engine::cost::CostModelEngine;
    use magnus::sim::MagnusPolicy;

    let g_max = args.get_u64("g-max", 64) as u32;
    let l_cap = args.get_u64("l-cap", 80) as u32;
    cfg.gpu.g_max = g_max;
    // A sharded trace maps one shard per instance; a single store is
    // shared by every instance — both replay through the same generic
    // cluster loop.
    let trace = match args.get("trace") {
        Some(path) => open_any(std::path::Path::new(path))?,
        None => LoadedTrace::Single(TraceStore::generate(&TraceSpec {
            rate: args.get_f64("rate", 8.0),
            n_requests: args.get_usize("requests", 400),
            g_max,
            l_cap,
            seed: cfg.seed,
            ..Default::default()
        })),
    };
    let plan = match args.get("fault-plan") {
        Some(spec) => FaultPlan::load(spec)?,
        None => FaultPlan::none(),
    };
    let copts = ClusterOptions {
        n_nodes: args.get_usize("instances", 4),
        hb_interval_s: args.get_f64("hb-interval", 1.0),
        suspect_after: args.get_u64("suspect-after", 2) as u32,
        steal_threshold_tokens: args.get_u64("steal-threshold", 64),
        route_seed: cfg.seed ^ 0x524f_5554,
    };
    let route_name = args.get_or("route", "jspq").to_ascii_lowercase();
    let mut route = if matches!(route_name.as_str(), "band" | "length" | "slice")
        && cfg.uncertainty.spill_confidence > 0.0
    {
        // Config-driven spillover: the banding policy honours the
        // uncertainty knob without a separate policy name.
        Box::new(magnus::cluster::LengthPartitioned {
            g_max,
            spill_threshold: cfg.uncertainty.spill_confidence as f32,
        }) as Box<dyn magnus::cluster::RoutePolicy>
    } else {
        parse_route_policy(&route_name, copts.route_seed, g_max).ok_or_else(|| {
            anyhow::anyhow!("unknown route policy {route_name:?} (one of {ROUTE_POLICY_NAMES:?})")
        })?
    };

    if let LoadedTrace::Sharded(sh) = &trace {
        anyhow::ensure!(
            copts.n_nodes == sh.n_shards(),
            "sharded trace has {} shards but --instances is {}; one shard maps to one \
             instance — pass --instances {} or regenerate with gen-trace --shards {}",
            sh.n_shards(),
            copts.n_nodes,
            sh.n_shards(),
            copts.n_nodes
        );
    }

    let split = build_predictor_split(LlmProfile::ChatGlm6B, 150, 5, g_max, cfg.seed);
    let mut predictor = GenLenPredictor::new(Variant::Usin, cfg);
    predictor.train(&split.train);

    if args.flag("live") {
        return cmd_serve_cluster_live(args, cfg, &copts, route.as_mut(), plan, predictor, trace);
    }

    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let policy = MagnusPolicy::magnus();
    let out = magnus::cluster::run_cluster_store(
        cfg,
        &policy,
        predictor,
        &engine,
        &trace,
        &plan,
        &copts,
        route.as_mut(),
    );
    let s = out.merged_metrics().summarise();
    println!(
        "serve-cluster {route_name} x{}: offered {} | completed {} | shed {} | \
         dup-acks {} | accounted: {}",
        copts.n_nodes, out.offered, out.completed, out.shed, out.duplicate_acks,
        out.accounted(),
    );
    println!(
        "  goodput {:.3} req/s | RT mean {:.2}s p50 {:.2}s p95 {:.2}s p99 {:.2}s \
         | imbalance {:.2} (simulated seconds)",
        s.request_throughput,
        s.mean_response_time,
        s.p50_response_time,
        s.p95_response_time,
        s.p99_response_time,
        out.imbalance_ratio(),
    );
    println!(
        "  failovers {} (mean recovery {:.2}s) | rejoins {} | reroutes {} | \
         steals {} | retries {} | restarts {} | fallback preds {} | mispredict {:.3}",
        out.failovers,
        out.mean_recovery_s(),
        out.rejoins,
        out.reroutes,
        out.steals,
        s.retries,
        s.worker_restarts,
        s.fallback_predictions,
        s.mispredict_rate,
    );
    Ok(())
}

/// `serve-cluster --live`: feed the trace through real threads — M
/// supervised cost-model cores behind the in-process router.
fn cmd_serve_cluster_live(
    args: &Args,
    cfg: &ServingConfig,
    copts: &magnus::cluster::ClusterOptions,
    route: &mut dyn magnus::cluster::RoutePolicy,
    plan: FaultPlan,
    mut predictor: GenLenPredictor,
    trace: LoadedTrace,
) -> anyhow::Result<()> {
    use std::sync::{mpsc, Arc};
    use std::time::Instant;

    use magnus::cluster::serve_cluster_ingress_sim;
    use magnus::server::{EdgeJob, LivePolicy, ServeOptions};
    use magnus::sim::MagnusPolicy;
    use magnus::util::time::clamped_duration;
    use magnus::workload::{ShardedTrace, TraceSource};

    let opts = ServeOptions {
        n_workers: args.get_usize("workers", 2),
        time_scale: args.get_f64("time-scale", 50.0),
        fault_plan: plan,
        ..Default::default()
    };
    let time_scale = opts.time_scale.max(1e-9);

    // One shared store, or one shard per core (ISSUE 10) — the router
    // then routes each job with its home shard attached.  The feeder
    // replays the shards as one global sequence either way.
    let stores = trace.shard_stores();
    let src = Arc::new(ShardedTrace::from_shards(stores.clone()));

    // Predict every request up front (the edge would do this at admission).
    let mut preds = Vec::with_capacity(src.len());
    {
        let views: Vec<_> = (0..src.len()).map(|i| src.view(i)).collect();
        predictor.predict_many_views(&views, &mut preds);
    }

    let (jtx, jrx) = mpsc::channel::<EdgeJob>();
    let (stx, srx) = mpsc::channel();
    let feeder = {
        let src = Arc::clone(&src);
        std::thread::spawn(move || {
            let t0 = Instant::now();
            for i in 0..src.len() {
                let meta = src.meta(i);
                let due = clamped_duration(meta.arrival / time_scale);
                let elapsed = t0.elapsed();
                if due > elapsed {
                    std::thread::sleep(due - elapsed);
                }
                if jtx
                    .send(EdgeJob {
                        meta,
                        predicted_gen_len: preds[i],
                    })
                    .is_err()
                {
                    break;
                }
            }
        })
    };
    let make_policy = || LivePolicy::Magnus(MagnusPolicy::magnus());
    let report = serve_cluster_ingress_sim(
        cfg,
        &opts,
        copts,
        &make_policy,
        route,
        jrx,
        stx,
        stores,
    )?;
    feeder.join().ok();
    // Drain the edge-facing signal channel (no HTTP layer here).
    let mut signals = 0usize;
    while srx.try_recv().is_ok() {
        signals += 1;
    }
    println!(
        "serve-cluster --live {} x{}: offered {} | completed {} | shed {} | \
         dup-signals {} | accounted: {}",
        route.name(),
        copts.n_nodes,
        report.offered,
        report.completed,
        report.shed,
        report.duplicate_signals,
        report.accounted(),
    );
    println!(
        "  failovers {} | reroutes {} | respawns {} | core-failures {} | \
         terminal signals {} (wall-clock run, time-scale {})",
        report.failovers,
        report.reroutes,
        report.respawns,
        report.core_failures,
        signals,
        opts.time_scale,
    );
    Ok(())
}

/// Run the HTTP front door over the cost-model cluster until Ctrl-C-ish
/// (`--duration` seconds), then drain gracefully and print the ledger.
fn cmd_serve_edge(args: &Args, cfg: &mut ServingConfig) -> anyhow::Result<()> {
    use std::sync::Arc;
    use std::time::Duration;

    use magnus::edge::{AdmissionConfig, EdgeOptions, EdgeServer};
    use magnus::http::HttpConfig;
    use magnus::server::LivePolicy;
    use magnus::sim::MagnusPolicy;

    let g_max = args.get_u64("g-max", 64) as u32;
    cfg.gpu.g_max = g_max;
    let store = match args.get("trace") {
        Some(path) => Arc::new(load_single_trace(path, "serve-edge", explicit_requests(args))?),
        None => Arc::new(TraceStore::generate(&TraceSpec {
            rate: args.get_f64("rate", 5.0),
            n_requests: args.get_usize("requests", 256),
            g_max,
            l_cap: args.get_u64("l-cap", 80) as u32,
            seed: cfg.seed,
            ..Default::default()
        })),
    };
    let split = build_predictor_split(LlmProfile::ChatGlm6B, 150, 5, g_max, cfg.seed);
    let mut predictor = GenLenPredictor::new(Variant::Usin, cfg);
    predictor.train(&split.train);

    let opts = EdgeOptions {
        http: HttpConfig {
            addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
            ..Default::default()
        },
        admission: AdmissionConfig {
            queue_cap: args.get_usize("queue-cap", 64),
            token_budget: args.get_u64("token-budget", 4096),
            rps_limit: args.get_f64("rps-limit", f64::INFINITY),
            default_deadline_s: args.get_f64("deadline", 30.0),
            max_deadline_s: args.get_f64("max-deadline", 120.0),
        },
        n_workers: args.get_usize("workers", 2),
        time_scale: args.get_f64("time-scale", 50.0),
        fault_plan: match args.get("fault-plan") {
            Some(spec) => FaultPlan::load(spec)?,
            None => FaultPlan::none(),
        },
        drain_grace: Duration::from_secs(args.get_u64("drain-grace", 30)),
    };
    let n_entries = store.len();
    let edge = EdgeServer::start(
        cfg,
        &opts,
        LivePolicy::Magnus(MagnusPolicy::magnus()),
        Some(predictor),
        store,
    )?;
    println!(
        "edge listening on {} ({n_entries} trace entries; POST /v1/generate, \
         GET /metrics, /healthz)",
        edge.addr(),
    );
    std::thread::sleep(Duration::from_secs_f64(args.get_f64("duration", 60.0)));
    println!("draining...");
    let r = edge.shutdown()?;
    println!(
        "edge: offered {} | completed {} | shed {} | expired {} | core-shed {} | \
         bad {} | goodput {:.2} rps | p50 {:.3}s p99 {:.3}s | accounted: {}",
        r.offered,
        r.completed,
        r.shed,
        r.expired,
        r.core_shed,
        r.bad_requests,
        r.goodput(),
        r.latency.quantile(50.0),
        r.latency.quantile(99.0),
        r.accounted()
    );
    Ok(())
}

/// Open-loop load against a live edge (`serve-edge`, or anything
/// speaking the same three endpoints).
fn cmd_load_gen(args: &Args) -> anyhow::Result<()> {
    use magnus::edge::{run_loadgen, LoadGenConfig};

    let burst = args.get("burst").and_then(|s| {
        let (p, f) = s.split_once('@')?;
        Some((p.parse::<f64>().ok()?, f.parse::<f64>().ok()?))
    });
    let plan = match args.get("fault-plan") {
        Some(spec) => FaultPlan::load(spec)?,
        None => FaultPlan::none(),
    };
    let cfg = LoadGenConfig {
        addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
        rps: args.get_f64("rps", 50.0),
        n_requests: args.get_usize("requests", 500),
        trace_len: args.get_usize("trace-len", 256),
        burst,
        n_conns: args.get_usize("conns", 8),
        deadline_ms: args.get("deadline-ms").and_then(|s| s.parse().ok()),
        plan,
        seed: args.get_u64("seed", 1),
    };
    let r = run_loadgen(&cfg)?;
    println!(
        "load-gen: offered {} @ {:.1} rps{} | ok {} | shed {} | expired {} | \
         dropped {} | client-err {} | goodput {:.2} rps | p50 {:.3}s p99 {:.3}s | \
         max lag {:.3}s | accounted: {}",
        r.offered,
        cfg.rps,
        if cfg.burst.is_some() { " (bursty)" } else { "" },
        r.ok,
        r.shed,
        r.expired,
        r.dropped,
        r.client_errors,
        r.goodput(),
        r.latency.quantile(50.0),
        r.latency.quantile(99.0),
        r.max_lag_s,
        r.accounted()
    );
    Ok(())
}
