//! Deterministic fault injection: a seeded, replayable [`FaultPlan`]
//! consumed by the cost-model simulator ([`crate::sim::magnus`]) and the
//! live supervised server ([`crate::server`]).
//!
//! Every fault decision is a pure hash of `(plan seed, fault kind,
//! decision coordinates)` — no RNG state threads through the serving
//! loop, so a retried batch redraws deterministically, two runs of the
//! same plan are bit-identical, and an empty plan adds **zero** float
//! operations to the fault-free path (the callers branch to the legacy
//! code before any hash is computed).
//!
//! The taxonomy (tested end-to-end by `tests/chaos.rs`):
//! * worker **crash** with probability `crash_p` per dispatch — the
//!   instance dies mid-serve and restarts with capped exponential
//!   backoff;
//! * **transient serve error** with probability `serve_error_p` — the
//!   serve fails but the instance survives;
//! * engine **stall** windows — serving/wasted times are multiplied by a
//!   slowdown factor while the window is open;
//! * forced-**OOM storms** — inside the window, batches that would have
//!   completed are killed at a mid-generation iteration with probability
//!   `p` (memory-pressure bursts the cost model alone would never emit);
//! * **predictor outages** — windows during which the trained forest is
//!   unreachable and admission falls back per
//!   [`FallbackMode`](crate::predictor::FallbackMode);
//! * **predictor noise** — multiplicative jitter + additive bias on
//!   every prediction (a degraded-but-online predictor);
//! * **connection drop** — the load generator abandons the connection
//!   mid-request with probability `conn_drop_p` (the server must reap
//!   the dead socket without leaking the admission slot);
//! * **slow client** — the load generator stalls `slow_client_delay_s`
//!   mid-request-write with probability `slow_client_p` (the server's
//!   read timeout must bound the damage).
//!
//! The last two are *client-side* adversity: they are consumed by
//! [`crate::edge::loadgen`], which injects them against the socket so
//! the edge/http path is exercised, not simulated.

use crate::predictor::FallbackMode;
use crate::util::Json;

/// Half-open time window `[start, end)` in sim/replayed seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Window {
    pub start: f64,
    pub end: f64,
}

impl Window {
    pub fn new(start: f64, end: f64) -> Window {
        Window { start, end }
    }

    #[inline]
    pub fn contains(&self, t: f64) -> bool {
        t >= self.start && t < self.end
    }
}

/// Engine slowdown: serving/wasted times are multiplied by `factor`
/// while `window` is open (overlapping stalls compound).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stall {
    pub window: Window,
    pub factor: f64,
}

/// Forced-OOM burst: inside `window`, a batch that would have completed
/// is killed mid-generation with probability `p` per dispatch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OomStorm {
    pub window: Window,
    pub p: f64,
}

/// Predictor-offline window and which fallback admission uses during it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictorOutage {
    pub window: Window,
    pub mode: FallbackMode,
}

/// Degraded-but-online predictor: every prediction is scaled by a
/// deterministic per-request jitter in `[1 - jitter, 1 + jitter)` and
/// shifted by `bias`, then re-clamped to `[1, G_max]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictorNoise {
    pub bias: f64,
    pub jitter: f64,
}

/// Workload-drift window (ISSUE 9): while open, every *trained*
/// prediction is scaled by `1 + bias` (a fractional multiplicative
/// shift; overlapping windows add their biases) and re-clamped to
/// `[1, G_max]`.  This models the serving distribution drifting away
/// from the training distribution — the forest's outputs become
/// systematically wrong relative to the actual generations, while the
/// forest-free fallback rungs (UIL heuristic, max-bucket) are
/// unaffected, which is exactly what makes drift-triggered demotion
/// worth doing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftWindow {
    pub window: Window,
    /// Fractional bias, e.g. `-0.3` = trained predictions land 30 % low.
    pub bias: f64,
}

/// Per-application predictor outage (ISSUE 9): inside the window,
/// requests of application index `app` (position in
/// [`App::ALL`](crate::workload::App::ALL)) are admitted through the
/// fallback chain while every other app keeps trained predictions — a
/// partial-degradation axis the global [`PredictorOutage`] cannot
/// express.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AppOutage {
    /// Application index in `App::ALL`.
    pub app: usize,
    pub window: Window,
    pub mode: FallbackMode,
}

/// Cluster-level fault (ISSUE 8): instance `instance` is dead for the
/// whole window — it serves nothing, fails heartbeats, and its queued +
/// in-flight work must fail over through the router.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstKill {
    pub instance: usize,
    pub window: Window,
}

/// Cluster-level fault: instance `instance` serves `factor`× slower
/// inside the window (a degraded-but-alive straggler; overlapping
/// windows on the same instance compound).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstSlow {
    pub instance: usize,
    pub window: Window,
    pub factor: f64,
}

/// Cluster-level fault: instance `instance` keeps serving inside the
/// window but its completions stop reaching the router until the window
/// closes (a network partition: work is not lost, acks are late — the
/// router may have failed the requests over in the meantime, so late
/// duplicates must be deduplicated at the cluster ledger).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstPartition {
    pub instance: usize,
    pub window: Window,
}

/// A seeded, replayable fault schedule.  [`FaultPlan::none`] is the
/// explicit no-fault plan; consumers treat it as "run the legacy path
/// byte-for-byte" (checked by [`FaultPlan::is_noop`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed of every fault decision (independent of the workload seed).
    pub seed: u64,
    /// Per-dispatch probability that the serving instance crashes.
    pub crash_p: f64,
    /// Per-dispatch probability of a transient serve error.
    pub serve_error_p: f64,
    pub stalls: Vec<Stall>,
    pub oom_storms: Vec<OomStorm>,
    pub predictor_outages: Vec<PredictorOutage>,
    pub predictor_noise: Option<PredictorNoise>,
    /// Workload-drift windows: trained predictions biased by `1 + bias`
    /// while open (overlapping windows add biases).
    pub drift_windows: Vec<DriftWindow>,
    /// Per-application predictor outages.
    pub app_outages: Vec<AppOutage>,
    /// Injected-fault re-dispatches allowed per batch before its
    /// requests are recorded as shed (OOM splits are not retries).
    pub max_retries: u32,
    /// Restarts allowed per worker before the supervisor retires it.
    pub max_worker_restarts: u32,
    /// Base of the capped exponential restart backoff (seconds).
    pub restart_backoff_s: f64,
    /// §III-C alternative on OOM: split on observed EOS and re-bucket
    /// the overrunning half ([`crate::batch::Batch::split_overrun`])
    /// instead of splitting evenly.
    pub overrun_guard: bool,
    /// Per-request probability that the load generator drops the
    /// connection mid-request (client-side; socket path only).
    pub conn_drop_p: f64,
    /// Per-request probability that the load generator stalls
    /// mid-request-write (client-side; socket path only).
    pub slow_client_p: f64,
    /// How long a slow client stalls before finishing its write (s).
    pub slow_client_delay_s: f64,
    /// Cluster axes (ISSUE 8): whole-instance kill windows.
    pub inst_kills: Vec<InstKill>,
    /// Cluster axes: slow-instance stall windows.
    pub inst_slows: Vec<InstSlow>,
    /// Cluster axes: partition (stop-acking) windows.
    pub inst_partitions: Vec<InstPartition>,
}

/// Fault-kind salts for the decision hash (distinct streams per axis).
const K_CRASH: u64 = 1;
const K_ERROR: u64 = 2;
const K_OOM: u64 = 3;
const K_WASTE: u64 = 4;
const K_NOISE: u64 = 5;
const K_CONN_DROP: u64 = 6;
const K_SLOW: u64 = 7;

/// SplitMix64 finalizer (same mixer as `util::rng`, reimplemented here
/// because the plan hashes coordinates statelessly instead of advancing
/// a generator).
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

impl FaultPlan {
    /// The explicit no-fault plan (every consumer's default).
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            crash_p: 0.0,
            serve_error_p: 0.0,
            stalls: Vec::new(),
            oom_storms: Vec::new(),
            predictor_outages: Vec::new(),
            predictor_noise: None,
            drift_windows: Vec::new(),
            app_outages: Vec::new(),
            max_retries: 3,
            max_worker_restarts: 4,
            restart_backoff_s: 0.25,
            overrun_guard: false,
            conn_drop_p: 0.0,
            slow_client_p: 0.0,
            slow_client_delay_s: 0.05,
            inst_kills: Vec::new(),
            inst_slows: Vec::new(),
            inst_partitions: Vec::new(),
        }
    }

    /// True when the plan injects nothing at all — consumers take the
    /// legacy byte-identical path (golden equivalence depends on it).
    pub fn is_noop(&self) -> bool {
        self.crash_p <= 0.0
            && self.serve_error_p <= 0.0
            && self.stalls.is_empty()
            && self.oom_storms.is_empty()
            && !self.has_predictor_faults()
            && !self.overrun_guard
            && self.conn_drop_p <= 0.0
            && self.slow_client_p <= 0.0
            && !self.has_instance_faults()
    }

    /// True when the plan carries any cluster-level (whole-instance)
    /// fault axis — the cluster router branches off its legacy
    /// fast path on this, mirroring [`FaultPlan::is_noop`].
    pub fn has_instance_faults(&self) -> bool {
        !self.inst_kills.is_empty()
            || !self.inst_slows.is_empty()
            || !self.inst_partitions.is_empty()
    }

    /// Is cluster instance `i` inside one of its kill windows at `now`?
    /// A dead instance serves nothing and fails its heartbeats.
    pub fn instance_dead(&self, i: usize, now: f64) -> bool {
        self.inst_kills
            .iter()
            .any(|k| k.instance == i && k.window.contains(now))
    }

    /// Product of every open slow-instance factor for instance `i`
    /// (1.0 when none is open) — composes with the engine-level
    /// [`FaultPlan::stall_factor`].
    pub fn instance_stall(&self, i: usize, now: f64) -> f64 {
        let mut f = 1.0;
        for s in &self.inst_slows {
            if s.instance == i && s.window.contains(now) {
                f *= s.factor;
            }
        }
        f
    }

    /// Is cluster instance `i` partitioned (serving but not acking) at
    /// `now`?
    pub fn instance_partitioned(&self, i: usize, now: f64) -> bool {
        self.inst_partitions
            .iter()
            .any(|p| p.instance == i && p.window.contains(now))
    }

    /// End of the partition window covering instance `i` at `now` (when
    /// its deferred acks will be delivered).
    pub fn partition_end(&self, i: usize, now: f64) -> Option<f64> {
        self.inst_partitions
            .iter()
            .filter(|p| p.instance == i && p.window.contains(now))
            .map(|p| p.window.end)
            .fold(None, |acc, e| Some(acc.map_or(e, |a: f64| a.max(e))))
    }

    /// End of the kill window covering instance `i` at `now` (when the
    /// instance reboots and its slots come back online).
    pub fn kill_end(&self, i: usize, now: f64) -> Option<f64> {
        self.inst_kills
            .iter()
            .filter(|k| k.instance == i && k.window.contains(now))
            .map(|k| k.window.end)
            .fold(None, |acc, e| Some(acc.map_or(e, |a: f64| a.max(e))))
    }

    /// True when admission must route predictions through the fallback/
    /// noise/drift chain instead of the exact legacy batch-predict call.
    pub fn has_predictor_faults(&self) -> bool {
        !self.predictor_outages.is_empty()
            || self.predictor_noise.is_some()
            || !self.drift_windows.is_empty()
            || !self.app_outages.is_empty()
    }

    /// Sum of every open drift-window bias (0.0 when none is open).
    pub fn drift_bias(&self, now: f64) -> f64 {
        let mut bias = 0.0;
        for d in &self.drift_windows {
            if d.window.contains(now) {
                bias += d.bias;
            }
        }
        bias
    }

    /// Apply the open drift bias to one *trained* prediction (identity
    /// when no window is open).  Clamped to `[1, G_max]` like the
    /// predictor; fallback-rung predictions must NOT pass through here —
    /// the forest drifted, the UIL heuristic did not.
    pub fn drifted_prediction(&self, predicted: u32, now: f64, g_max: u32) -> u32 {
        if self.drift_windows.is_empty() {
            return predicted;
        }
        let bias = self.drift_bias(now);
        if bias == 0.0 {
            return predicted;
        }
        let raw = predicted as f64 * (1.0 + bias);
        (raw.round().max(1.0) as u32).min(g_max.max(1))
    }

    /// The fallback mode of the first per-app outage window covering
    /// application index `app` (position in `App::ALL`) at `now`.
    pub fn app_outage(&self, app: usize, now: f64) -> Option<FallbackMode> {
        self.app_outages
            .iter()
            .find(|o| o.app == app && o.window.contains(now))
            .map(|o| o.mode)
    }

    /// Stateless uniform draw in `[0, 1)` for `(kind, a, b)`.
    #[inline]
    fn unit(&self, kind: u64, a: u64, b: u64) -> f64 {
        let h = mix(mix(mix(self.seed ^ kind.wrapping_mul(GOLDEN)) ^ a) ^ b);
        (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Does dispatch `attempt` of `batch_id` crash its instance?
    #[inline]
    pub fn injects_crash(&self, batch_id: u64, attempt: u64) -> bool {
        self.crash_p > 0.0 && self.unit(K_CRASH, batch_id, attempt) < self.crash_p
    }

    /// Does dispatch `attempt` of `batch_id` fail transiently?
    #[inline]
    pub fn injects_serve_error(&self, batch_id: u64, attempt: u64) -> bool {
        self.serve_error_p > 0.0 && self.unit(K_ERROR, batch_id, attempt) < self.serve_error_p
    }

    /// Is this dispatch killed by an open OOM storm?
    pub fn forced_oom(&self, now: f64, batch_id: u64, attempt: u64) -> bool {
        self.oom_storms
            .iter()
            .any(|s| s.window.contains(now) && self.unit(K_OOM, batch_id, attempt) < s.p)
    }

    /// Product of every open stall factor (1.0 when none is open).
    pub fn stall_factor(&self, now: f64) -> f64 {
        let mut f = 1.0;
        for s in &self.stalls {
            if s.window.contains(now) {
                f *= s.factor;
            }
        }
        f
    }

    /// Fraction of the nominal serve time burned before an injected
    /// crash/error/forced-OOM surfaces, in `[0, 1)`.
    #[inline]
    pub fn wasted_fraction(&self, batch_id: u64, attempt: u64) -> f64 {
        self.unit(K_WASTE, batch_id, attempt)
    }

    /// Does the load generator abandon request `serial` mid-flight?
    #[inline]
    pub fn injects_conn_drop(&self, serial: u64) -> bool {
        self.conn_drop_p > 0.0 && self.unit(K_CONN_DROP, serial, 0) < self.conn_drop_p
    }

    /// Does the load generator stall mid-write on request `serial`?
    #[inline]
    pub fn injects_slow_client(&self, serial: u64) -> bool {
        self.slow_client_p > 0.0 && self.unit(K_SLOW, serial, 0) < self.slow_client_p
    }

    /// The fallback mode of the first outage window containing `now`.
    pub fn predictor_outage(&self, now: f64) -> Option<FallbackMode> {
        self.predictor_outages
            .iter()
            .find(|o| o.window.contains(now))
            .map(|o| o.mode)
    }

    /// Apply predictor noise to one prediction (identity when the plan
    /// has no noise axis).  Clamped to `[1, G_max]` like the predictor.
    pub fn noisy_prediction(&self, predicted: u32, request_id: u64, g_max: u32) -> u32 {
        match &self.predictor_noise {
            None => predicted,
            Some(n) => {
                let u = self.unit(K_NOISE, request_id, 0);
                let raw = predicted as f64 * (1.0 + n.jitter * (2.0 * u - 1.0)) + n.bias;
                (raw.round().max(1.0) as u32).min(g_max.max(1))
            }
        }
    }

    /// Capped exponential backoff before a worker's restart number
    /// `restarts` (0-based): `base * 2^min(restarts, 5)`.
    pub fn restart_backoff(&self, restarts: u32) -> f64 {
        self.restart_backoff_s.max(0.0) * f64::from(1u32 << restarts.min(5))
    }

    // ------------------------------------------------------ persistence ---

    /// Load a plan from `arg`: a JSON file path if one exists there,
    /// otherwise an inline spec string (see [`FaultPlan::parse_spec`]).
    pub fn load(arg: &str) -> anyhow::Result<FaultPlan> {
        if std::path::Path::new(arg).exists() {
            let text = std::fs::read_to_string(arg)?;
            let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
            FaultPlan::from_json(&j)
        } else {
            FaultPlan::parse_spec(arg)
        }
    }

    /// Parse a compact comma-separated spec, e.g.
    /// `seed=7,crash=0.1,err=0.05,stall=10..40@3,oom=0..1e9@0.2,predoff=5..25,noise=8@0.5,guard`.
    ///
    /// Keys: `seed=N`, `crash=P`, `err=P`, `stall=A..B@FACTOR`,
    /// `oom=A..B@P`, `predoff=A..B[:heuristic|:max]` (default heuristic),
    /// `noise=BIAS@JITTER`, `drift=A..B@BIAS` (trained predictions
    /// scaled by `1 + BIAS` inside the window; may repeat),
    /// `appoff=APP:A..B[:heuristic|:max]` (per-application outage, APP =
    /// index in `App::ALL`; may repeat), `retries=N`, `restarts=N`,
    /// `backoff=S`, `conndrop=P`, `slowclient=P@DELAY_S` (client-side
    /// socket adversity), the cluster axes `ikill=I:A..B` (instance I
    /// dead in window), `islow=I:A..B@FACTOR` (instance I slowed) and
    /// `ipart=I:A..B` (instance I partitioned — serving, not acking;
    /// each may repeat to accumulate windows), and the bare flag `guard`
    /// (overrun re-bucketing on OOM).
    ///
    /// Malformed specs name the offending clause: `drift=5..@` fails
    /// with ``fault spec clause `drift=5..@`: …``, not a blanket parse
    /// error.
    pub fn parse_spec(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::none();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            apply_clause(&mut plan, part)
                .map_err(|e| anyhow::anyhow!("fault spec clause `{part}`: {e}"))?;
        }
        Ok(plan)
    }

    /// JSON form (round-trips through [`FaultPlan::from_json`]).  Note
    /// the seed travels as a JSON number: exact up to 2^53.
    pub fn to_json(&self) -> Json {
        let win = |w: &Window| vec![("start", Json::num(w.start)), ("end", Json::num(w.end))];
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("crash_p", Json::num(self.crash_p)),
            ("serve_error_p", Json::num(self.serve_error_p)),
            (
                "stalls",
                Json::Arr(
                    self.stalls
                        .iter()
                        .map(|s| {
                            let mut f = win(&s.window);
                            f.push(("factor", Json::num(s.factor)));
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
            (
                "oom_storms",
                Json::Arr(
                    self.oom_storms
                        .iter()
                        .map(|s| {
                            let mut f = win(&s.window);
                            f.push(("p", Json::num(s.p)));
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
            (
                "predictor_outages",
                Json::Arr(
                    self.predictor_outages
                        .iter()
                        .map(|o| {
                            let mut f = win(&o.window);
                            f.push(("mode", Json::str(mode_name(o.mode))));
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
            (
                "predictor_noise",
                match &self.predictor_noise {
                    None => Json::Null,
                    Some(n) => Json::obj(vec![
                        ("bias", Json::num(n.bias)),
                        ("jitter", Json::num(n.jitter)),
                    ]),
                },
            ),
            (
                "drift_windows",
                Json::Arr(
                    self.drift_windows
                        .iter()
                        .map(|d| {
                            let mut f = win(&d.window);
                            f.push(("bias", Json::num(d.bias)));
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
            (
                "app_outages",
                Json::Arr(
                    self.app_outages
                        .iter()
                        .map(|o| {
                            let mut f = win(&o.window);
                            f.push(("app", Json::num(o.app as f64)));
                            f.push(("mode", Json::str(mode_name(o.mode))));
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
            ("max_retries", Json::num(self.max_retries)),
            ("max_worker_restarts", Json::num(self.max_worker_restarts)),
            ("restart_backoff_s", Json::num(self.restart_backoff_s)),
            ("overrun_guard", Json::Bool(self.overrun_guard)),
            ("conn_drop_p", Json::num(self.conn_drop_p)),
            ("slow_client_p", Json::num(self.slow_client_p)),
            ("slow_client_delay_s", Json::num(self.slow_client_delay_s)),
            (
                "inst_kills",
                Json::Arr(
                    self.inst_kills
                        .iter()
                        .map(|k| {
                            let mut f = win(&k.window);
                            f.push(("instance", Json::num(k.instance as f64)));
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
            (
                "inst_slows",
                Json::Arr(
                    self.inst_slows
                        .iter()
                        .map(|s| {
                            let mut f = win(&s.window);
                            f.push(("instance", Json::num(s.instance as f64)));
                            f.push(("factor", Json::num(s.factor)));
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
            (
                "inst_partitions",
                Json::Arr(
                    self.inst_partitions
                        .iter()
                        .map(|p| {
                            let mut f = win(&p.window);
                            f.push(("instance", Json::num(p.instance as f64)));
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse the JSON form; missing fields keep [`FaultPlan::none`]
    /// defaults, so a partial plan file is valid.
    pub fn from_json(j: &Json) -> anyhow::Result<FaultPlan> {
        let mut plan = FaultPlan::none();
        if let Some(v) = j.get("seed").as_u64() {
            plan.seed = v;
        }
        plan.crash_p = j.get("crash_p").as_f64().unwrap_or(plan.crash_p);
        plan.serve_error_p = j.get("serve_error_p").as_f64().unwrap_or(plan.serve_error_p);
        if let Some(xs) = j.get("stalls").as_arr() {
            for x in xs {
                plan.stalls.push(Stall {
                    window: window_json(x)?,
                    factor: req_f64(x, "factor")?,
                });
            }
        }
        if let Some(xs) = j.get("oom_storms").as_arr() {
            for x in xs {
                plan.oom_storms.push(OomStorm {
                    window: window_json(x)?,
                    p: req_f64(x, "p")?,
                });
            }
        }
        if let Some(xs) = j.get("predictor_outages").as_arr() {
            for x in xs {
                let mode = match x.get("mode").as_str() {
                    None | Some("heuristic") => FallbackMode::Heuristic,
                    Some("max-bucket") | Some("max") => FallbackMode::MaxBucket,
                    Some(m) => anyhow::bail!("unknown fallback mode `{m}`"),
                };
                plan.predictor_outages.push(PredictorOutage {
                    window: window_json(x)?,
                    mode,
                });
            }
        }
        let noise = j.get("predictor_noise");
        if !matches!(noise, Json::Null) {
            plan.predictor_noise = Some(PredictorNoise {
                bias: req_f64(noise, "bias")?,
                jitter: req_f64(noise, "jitter")?,
            });
        }
        if let Some(xs) = j.get("drift_windows").as_arr() {
            for x in xs {
                plan.drift_windows.push(DriftWindow {
                    window: window_json(x)?,
                    bias: req_f64(x, "bias")?,
                });
            }
        }
        if let Some(xs) = j.get("app_outages").as_arr() {
            for x in xs {
                let mode = match x.get("mode").as_str() {
                    None | Some("heuristic") => FallbackMode::Heuristic,
                    Some("max-bucket") | Some("max") => FallbackMode::MaxBucket,
                    Some(m) => anyhow::bail!("unknown fallback mode `{m}`"),
                };
                plan.app_outages.push(AppOutage {
                    app: req_usize(x, "app")?,
                    window: window_json(x)?,
                    mode,
                });
            }
        }
        if let Some(v) = j.get("max_retries").as_u64() {
            plan.max_retries = v as u32;
        }
        if let Some(v) = j.get("max_worker_restarts").as_u64() {
            plan.max_worker_restarts = v as u32;
        }
        plan.restart_backoff_s =
            j.get("restart_backoff_s").as_f64().unwrap_or(plan.restart_backoff_s);
        if let Some(b) = j.get("overrun_guard").as_bool() {
            plan.overrun_guard = b;
        }
        plan.conn_drop_p = j.get("conn_drop_p").as_f64().unwrap_or(plan.conn_drop_p);
        plan.slow_client_p = j.get("slow_client_p").as_f64().unwrap_or(plan.slow_client_p);
        plan.slow_client_delay_s =
            j.get("slow_client_delay_s").as_f64().unwrap_or(plan.slow_client_delay_s);
        if let Some(xs) = j.get("inst_kills").as_arr() {
            for x in xs {
                plan.inst_kills.push(InstKill {
                    instance: req_usize(x, "instance")?,
                    window: window_json(x)?,
                });
            }
        }
        if let Some(xs) = j.get("inst_slows").as_arr() {
            for x in xs {
                plan.inst_slows.push(InstSlow {
                    instance: req_usize(x, "instance")?,
                    window: window_json(x)?,
                    factor: req_f64(x, "factor")?,
                });
            }
        }
        if let Some(xs) = j.get("inst_partitions").as_arr() {
            for x in xs {
                plan.inst_partitions.push(InstPartition {
                    instance: req_usize(x, "instance")?,
                    window: window_json(x)?,
                });
            }
        }
        Ok(plan)
    }
}

/// Apply one compact-spec clause to `plan`.  Errors describe what the
/// clause wanted; [`FaultPlan::parse_spec`] wraps them with the clause
/// text itself so the caller sees exactly which part of the spec is
/// malformed.
fn apply_clause(plan: &mut FaultPlan, part: &str) -> anyhow::Result<()> {
    if part == "guard" {
        plan.overrun_guard = true;
        return Ok(());
    }
    let (key, val) = part
        .split_once('=')
        .ok_or_else(|| anyhow::anyhow!("want key=value"))?;
    match key {
        "seed" => plan.seed = num(val)? as u64,
        "crash" => plan.crash_p = num(val)?,
        "err" => plan.serve_error_p = num(val)?,
        "retries" => plan.max_retries = num(val)? as u32,
        "restarts" => plan.max_worker_restarts = num(val)? as u32,
        "backoff" => plan.restart_backoff_s = num(val)?,
        "stall" => {
            let (window, factor) = window_at(val)?;
            plan.stalls.push(Stall { window, factor });
        }
        "oom" => {
            let (window, p) = window_at(val)?;
            plan.oom_storms.push(OomStorm { window, p });
        }
        "predoff" => {
            let (range, mode) = range_mode(val)?;
            plan.predictor_outages.push(PredictorOutage {
                window: window_of(range)?,
                mode,
            });
        }
        "noise" => {
            let (bias, jitter) = val
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("noise wants BIAS@JITTER, got `{val}`"))?;
            plan.predictor_noise = Some(PredictorNoise {
                bias: num(bias)?,
                jitter: num(jitter)?,
            });
        }
        "drift" => {
            let (window, bias) = window_at(val)?;
            plan.drift_windows.push(DriftWindow { window, bias });
        }
        "appoff" => {
            let (app, rest) = val.split_once(':').ok_or_else(|| {
                anyhow::anyhow!("appoff wants APP:A..B[:heuristic|:max], got `{val}`")
            })?;
            let app = app
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad app index `{app}`"))?;
            if app >= crate::workload::App::ALL.len() {
                anyhow::bail!(
                    "app index {app} out of range (apps 0..{})",
                    crate::workload::App::ALL.len()
                );
            }
            let (range, mode) = range_mode(rest)?;
            plan.app_outages.push(AppOutage {
                app,
                window: window_of(range)?,
                mode,
            });
        }
        "ikill" => {
            let (instance, rest) = inst_of(val)?;
            plan.inst_kills.push(InstKill {
                instance,
                window: window_of(rest)?,
            });
        }
        "islow" => {
            let (instance, rest) = inst_of(val)?;
            let (window, factor) = window_at(rest)?;
            plan.inst_slows.push(InstSlow {
                instance,
                window,
                factor,
            });
        }
        "ipart" => {
            let (instance, rest) = inst_of(val)?;
            plan.inst_partitions.push(InstPartition {
                instance,
                window: window_of(rest)?,
            });
        }
        "conndrop" => plan.conn_drop_p = num(val)?,
        "slowclient" => {
            let (p, delay) = val
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("slowclient wants P@DELAY_S, got `{val}`"))?;
            plan.slow_client_p = num(p)?;
            plan.slow_client_delay_s = num(delay)?;
        }
        _ => anyhow::bail!("unknown fault spec key `{key}`"),
    }
    Ok(())
}

/// Split an optional `:heuristic`/`:max` suffix off a window range.
fn range_mode(val: &str) -> anyhow::Result<(&str, FallbackMode)> {
    match val.split_once(':') {
        None => Ok((val, FallbackMode::Heuristic)),
        Some((r, "heuristic")) => Ok((r, FallbackMode::Heuristic)),
        Some((r, "max")) => Ok((r, FallbackMode::MaxBucket)),
        Some((_, m)) => anyhow::bail!("unknown fallback mode `{m}`"),
    }
}

fn mode_name(mode: FallbackMode) -> &'static str {
    match mode {
        FallbackMode::Heuristic => "heuristic",
        FallbackMode::MaxBucket => "max-bucket",
    }
}

fn num(s: &str) -> anyhow::Result<f64> {
    s.parse::<f64>().map_err(|_| anyhow::anyhow!("bad number `{s}` in fault spec"))
}

/// Parse `A..B` into a window.
fn window_of(s: &str) -> anyhow::Result<Window> {
    let (a, b) =
        s.split_once("..").ok_or_else(|| anyhow::anyhow!("bad window `{s}` (want A..B)"))?;
    Ok(Window::new(num(a)?, num(b)?))
}

/// Parse `A..B@X` into a window plus its attached value.
fn window_at(s: &str) -> anyhow::Result<(Window, f64)> {
    let (range, x) =
        s.split_once('@').ok_or_else(|| anyhow::anyhow!("bad value `{s}` (want A..B@X)"))?;
    Ok((window_of(range)?, num(x)?))
}

fn window_json(x: &Json) -> anyhow::Result<Window> {
    Ok(Window::new(req_f64(x, "start")?, req_f64(x, "end")?))
}

fn req_f64(x: &Json, key: &str) -> anyhow::Result<f64> {
    x.get(key).as_f64().ok_or_else(|| anyhow::anyhow!("fault plan JSON missing `{key}`"))
}

fn req_usize(x: &Json, key: &str) -> anyhow::Result<usize> {
    x.get(key)
        .as_u64()
        .map(|v| v as usize)
        .ok_or_else(|| anyhow::anyhow!("fault plan JSON missing `{key}`"))
}

/// Parse `I:rest` into an instance index plus the remaining spec.
fn inst_of(s: &str) -> anyhow::Result<(usize, &str)> {
    let (i, rest) = s
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("bad instance fault `{s}` (want I:A..B)"))?;
    let idx = i
        .parse::<usize>()
        .map_err(|_| anyhow::anyhow!("bad instance index `{i}` in fault spec"))?;
    Ok((idx, rest))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_probability_shaped() {
        let mut plan = FaultPlan::none();
        plan.seed = 42;
        plan.crash_p = 0.3;
        plan.serve_error_p = 0.3;
        let crashes: Vec<bool> = (0..2000).map(|b| plan.injects_crash(b, 0)).collect();
        assert_eq!(crashes, (0..2000).map(|b| plan.injects_crash(b, 0)).collect::<Vec<_>>());
        let rate = crashes.iter().filter(|&&c| c).count() as f64 / 2000.0;
        assert!((rate - 0.3).abs() < 0.05, "crash rate {rate}");
        // distinct attempts redraw; distinct kinds are independent streams
        assert!((0..2000).any(|b| plan.injects_crash(b, 0) != plan.injects_crash(b, 1)));
        assert!((0..2000).any(|b| plan.injects_crash(b, 0) != plan.injects_serve_error(b, 0)));
    }

    #[test]
    fn zero_probability_never_fires() {
        let plan = FaultPlan::none();
        assert!((0..500).all(|b| !plan.injects_crash(b, 0)));
        assert!((0..500).all(|b| !plan.injects_serve_error(b, 0)));
        assert!(!plan.forced_oom(1.0, 0, 0));
        assert!(plan.is_noop());
    }

    #[test]
    fn windows_gate_storms_and_stalls() {
        let mut plan = FaultPlan::none();
        plan.seed = 7;
        plan.oom_storms.push(OomStorm {
            window: Window::new(10.0, 20.0),
            p: 1.0,
        });
        plan.stalls.push(Stall {
            window: Window::new(10.0, 20.0),
            factor: 3.0,
        });
        plan.stalls.push(Stall {
            window: Window::new(15.0, 25.0),
            factor: 2.0,
        });
        assert!(!plan.forced_oom(9.9, 1, 0) && plan.forced_oom(10.0, 1, 0));
        assert!(plan.forced_oom(19.9, 1, 0) && !plan.forced_oom(20.0, 1, 0));
        assert_eq!(plan.stall_factor(5.0), 1.0);
        assert_eq!(plan.stall_factor(12.0), 3.0);
        assert_eq!(plan.stall_factor(17.0), 6.0);
        assert_eq!(plan.stall_factor(22.0), 2.0);
        assert!(!plan.is_noop());
    }

    #[test]
    fn predictor_outage_and_noise() {
        let mut plan = FaultPlan::none();
        plan.seed = 9;
        plan.predictor_outages.push(PredictorOutage {
            window: Window::new(5.0, 8.0),
            mode: FallbackMode::MaxBucket,
        });
        assert_eq!(plan.predictor_outage(6.0), Some(FallbackMode::MaxBucket));
        assert_eq!(plan.predictor_outage(8.0), None);
        // no noise axis: predictions pass through untouched
        assert_eq!(plan.noisy_prediction(17, 3, 64), 17);
        plan.predictor_noise = Some(PredictorNoise {
            bias: 1000.0,
            jitter: 0.0,
        });
        assert_eq!(plan.noisy_prediction(17, 3, 64), 64, "clamped to g_max");
        plan.predictor_noise = Some(PredictorNoise {
            bias: -1000.0,
            jitter: 0.0,
        });
        assert_eq!(plan.noisy_prediction(17, 3, 64), 1, "clamped to 1");
        plan.predictor_noise = Some(PredictorNoise {
            bias: 0.0,
            jitter: 0.5,
        });
        let jittered: Vec<u32> = (0..50).map(|id| plan.noisy_prediction(40, id, 1024)).collect();
        assert!(jittered.iter().any(|&g| g != 40), "jitter must perturb");
        assert!(jittered.iter().all(|&g| (20..=60).contains(&g)), "{jittered:?}");
    }

    #[test]
    fn client_side_axes_are_deterministic_and_gate_is_noop() {
        let plan = FaultPlan::none();
        assert!((0..500).all(|s| !plan.injects_conn_drop(s)));
        assert!((0..500).all(|s| !plan.injects_slow_client(s)));
        let mut chaos = FaultPlan::none();
        chaos.seed = 13;
        chaos.conn_drop_p = 0.25;
        chaos.slow_client_p = 0.25;
        assert!(!chaos.is_noop(), "client-side axes count as faults");
        let drops: Vec<bool> = (0..2000).map(|s| chaos.injects_conn_drop(s)).collect();
        assert_eq!(drops, (0..2000).map(|s| chaos.injects_conn_drop(s)).collect::<Vec<_>>());
        let rate = drops.iter().filter(|&&d| d).count() as f64 / 2000.0;
        assert!((rate - 0.25).abs() < 0.05, "conn-drop rate {rate}");
        // independent streams per axis
        assert!((0..2000).any(|s| chaos.injects_conn_drop(s) != chaos.injects_slow_client(s)));
    }

    #[test]
    fn restart_backoff_is_capped_exponential() {
        let plan = FaultPlan::none();
        assert_eq!(plan.restart_backoff(0), 0.25);
        assert_eq!(plan.restart_backoff(1), 0.5);
        assert_eq!(plan.restart_backoff(5), 8.0);
        assert_eq!(plan.restart_backoff(50), 8.0, "exponent capped");
    }

    #[test]
    fn instance_axes_window_semantics() {
        let mut plan = FaultPlan::none();
        plan.inst_kills.push(InstKill {
            instance: 1,
            window: Window::new(10.0, 20.0),
        });
        plan.inst_slows.push(InstSlow {
            instance: 0,
            window: Window::new(5.0, 15.0),
            factor: 3.0,
        });
        plan.inst_slows.push(InstSlow {
            instance: 0,
            window: Window::new(10.0, 25.0),
            factor: 2.0,
        });
        plan.inst_partitions.push(InstPartition {
            instance: 2,
            window: Window::new(30.0, 40.0),
        });
        assert!(!plan.is_noop(), "instance axes count as faults");
        assert!(plan.has_instance_faults());
        // kill gates on (instance, window)
        assert!(!plan.instance_dead(1, 9.9) && plan.instance_dead(1, 10.0));
        assert!(plan.instance_dead(1, 19.9) && !plan.instance_dead(1, 20.0));
        assert!(!plan.instance_dead(0, 12.0), "other instances unaffected");
        // slow factors compound per instance
        assert_eq!(plan.instance_stall(0, 7.0), 3.0);
        assert_eq!(plan.instance_stall(0, 12.0), 6.0);
        assert_eq!(plan.instance_stall(0, 20.0), 2.0);
        assert_eq!(plan.instance_stall(1, 12.0), 1.0);
        // partition + deferred-ack delivery time
        assert!(plan.instance_partitioned(2, 35.0) && !plan.instance_partitioned(2, 40.0));
        assert_eq!(plan.partition_end(2, 35.0), Some(40.0));
        assert_eq!(plan.partition_end(2, 45.0), None);
        assert_eq!(plan.partition_end(0, 35.0), None);
        // kill window end (instance reboot time)
        assert_eq!(plan.kill_end(1, 12.0), Some(20.0));
        assert_eq!(plan.kill_end(1, 25.0), None);
    }

    #[test]
    fn drift_windows_bias_trained_predictions_only_inside() {
        let mut plan = FaultPlan::none();
        plan.drift_windows.push(DriftWindow {
            window: Window::new(10.0, 20.0),
            bias: -0.3,
        });
        plan.drift_windows.push(DriftWindow {
            window: Window::new(15.0, 30.0),
            bias: -0.2,
        });
        assert!(!plan.is_noop(), "drift counts as a predictor fault");
        assert!(plan.has_predictor_faults());
        // Closed: identity, bit-exact.
        assert_eq!(plan.drifted_prediction(100, 5.0, 1024), 100);
        assert_eq!(plan.drift_bias(5.0), 0.0);
        // One window open: ×0.7.
        assert_eq!(plan.drifted_prediction(100, 12.0, 1024), 70);
        // Overlap adds biases: ×0.5.
        assert!((plan.drift_bias(17.0) + 0.5).abs() < 1e-12);
        assert_eq!(plan.drifted_prediction(100, 17.0, 1024), 50);
        // Clamps like the predictor.
        assert_eq!(plan.drifted_prediction(1, 17.0, 1024), 1);
        plan.drift_windows.push(DriftWindow {
            window: Window::new(40.0, 50.0),
            bias: 100.0,
        });
        assert_eq!(plan.drifted_prediction(100, 45.0, 64), 64);
    }

    #[test]
    fn app_outages_gate_per_application() {
        let mut plan = FaultPlan::none();
        plan.app_outages.push(AppOutage {
            app: 2,
            window: Window::new(10.0, 20.0),
            mode: FallbackMode::MaxBucket,
        });
        assert!(!plan.is_noop());
        assert!(plan.has_predictor_faults());
        assert_eq!(plan.app_outage(2, 15.0), Some(FallbackMode::MaxBucket));
        assert_eq!(plan.app_outage(2, 20.0), None, "half-open window");
        assert_eq!(plan.app_outage(1, 15.0), None, "other apps unaffected");
        // The *global* outage accessor is independent of the per-app axis.
        assert_eq!(plan.predictor_outage(15.0), None);
    }

    #[test]
    fn malformed_clauses_name_the_offender() {
        // Satellite: every malformed spec error must carry the offending
        // clause text, so multi-clause specs are debuggable.
        let cases = [
            ("drift=5..@", "drift=5..@"),
            ("seed=1,drift=5..@,crash=0.1", "drift=5..@"),
            ("appoff=x:1..2", "appoff=x:1..2"),
            ("appoff=9:1..2", "appoff=9:1..2"),
            ("appoff=1:1..2:turbo", "appoff=1:1..2:turbo"),
            ("appoff=0", "appoff=0"),
            ("stall=banana", "stall=banana"),
            ("noise=5", "noise=5"),
            ("predoff=1..2:warp", "predoff=1..2:warp"),
            ("ikill=10..20", "ikill=10..20"),
            ("crash", "crash"),
            ("bogus=1", "bogus=1"),
            ("slowclient=0.1", "slowclient=0.1"),
        ];
        for (spec, clause) in cases {
            let err = FaultPlan::parse_spec(spec).unwrap_err().to_string();
            assert!(
                err.contains(&format!("`{clause}`")),
                "spec `{spec}`: error `{err}` does not name clause `{clause}`"
            );
        }
        // Valid clauses around a bad one still parse up to the error.
        let err = FaultPlan::parse_spec("crash=0.5,drift=..@,err=0.1")
            .unwrap_err()
            .to_string();
        assert!(err.contains("`drift=..@`"), "{err}");
    }

    #[test]
    fn spec_parses_every_axis() {
        let plan = FaultPlan::parse_spec(
            "seed=7,crash=0.1,err=0.05,stall=10..40@3,oom=0..100@0.2,predoff=5..25:max,\
             noise=8@0.5,retries=2,restarts=6,backoff=0.1,conndrop=0.2,slowclient=0.1@0.4,\
             ikill=1:10..20,islow=0:5..15@3,ipart=2:30..40,ikill=3:50..60,\
             drift=100..200@-0.3,drift=150..250@0.1,appoff=4:10..30:max,appoff=0:40..50,guard",
        )
        .unwrap();
        assert_eq!(
            plan.drift_windows,
            vec![
                DriftWindow { window: Window::new(100.0, 200.0), bias: -0.3 },
                DriftWindow { window: Window::new(150.0, 250.0), bias: 0.1 },
            ],
            "drift windows accumulate"
        );
        assert_eq!(
            plan.app_outages,
            vec![
                AppOutage { app: 4, window: Window::new(10.0, 30.0), mode: FallbackMode::MaxBucket },
                AppOutage { app: 0, window: Window::new(40.0, 50.0), mode: FallbackMode::Heuristic },
            ],
            "per-app outages accumulate; mode defaults to heuristic"
        );
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.crash_p, 0.1);
        assert_eq!(plan.serve_error_p, 0.05);
        assert_eq!(plan.stalls, vec![Stall { window: Window::new(10.0, 40.0), factor: 3.0 }]);
        assert_eq!(plan.oom_storms, vec![OomStorm { window: Window::new(0.0, 100.0), p: 0.2 }]);
        assert_eq!(
            plan.predictor_outages,
            vec![PredictorOutage { window: Window::new(5.0, 25.0), mode: FallbackMode::MaxBucket }]
        );
        assert_eq!(plan.predictor_noise, Some(PredictorNoise { bias: 8.0, jitter: 0.5 }));
        assert_eq!((plan.max_retries, plan.max_worker_restarts), (2, 6));
        assert_eq!(plan.restart_backoff_s, 0.1);
        assert!(plan.overrun_guard);
        assert_eq!(plan.conn_drop_p, 0.2);
        assert_eq!((plan.slow_client_p, plan.slow_client_delay_s), (0.1, 0.4));
        assert_eq!(
            plan.inst_kills,
            vec![
                InstKill { instance: 1, window: Window::new(10.0, 20.0) },
                InstKill { instance: 3, window: Window::new(50.0, 60.0) },
            ],
            "repeated keys accumulate"
        );
        assert_eq!(
            plan.inst_slows,
            vec![InstSlow { instance: 0, window: Window::new(5.0, 15.0), factor: 3.0 }]
        );
        assert_eq!(
            plan.inst_partitions,
            vec![InstPartition { instance: 2, window: Window::new(30.0, 40.0) }]
        );
        assert!(FaultPlan::parse_spec("nope=1").is_err());
        assert!(FaultPlan::parse_spec("stall=banana").is_err());
        assert!(FaultPlan::parse_spec("ikill=10..20").is_err(), "missing instance index");
        assert!(FaultPlan::parse_spec("islow=x:1..2@3").is_err());
        assert_eq!(FaultPlan::parse_spec("").unwrap(), FaultPlan::none());
    }

    #[test]
    fn json_roundtrip_preserves_plan() {
        let plan = FaultPlan::parse_spec(
            "seed=11,crash=0.2,err=0.1,stall=1..2@4,oom=3..4@0.5,predoff=5..6,noise=2@0.25,\
             conndrop=0.3,slowclient=0.2@0.05,ikill=0:1..2,islow=1:2..3@5,ipart=2:4..6,\
             drift=7..9@-0.4,appoff=3:8..12:max,appoff=1:20..25,guard",
        )
        .unwrap();
        assert!(!plan.drift_windows.is_empty() && plan.app_outages.len() == 2);
        let back = FaultPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back, plan);
        let reparsed =
            FaultPlan::from_json(&Json::parse(&plan.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(reparsed, plan);
        // partial JSON keeps defaults
        let partial = FaultPlan::from_json(&Json::parse("{\"crash_p\": 0.5}").unwrap()).unwrap();
        assert_eq!(partial.crash_p, 0.5);
        assert_eq!(partial.max_retries, FaultPlan::none().max_retries);
    }
}
