//! Continuous learning (paper §III-B, §III-D, Fig. 14).
//!
//! Periodically sweep the log database for badly-predicted requests /
//! badly-estimated batches, augment the train sets, and refit.  In the
//! simulator the sweeps run at sim-time boundaries; in the live server a
//! background thread calls `tick` with wall time.  Retraining is
//! asynchronous to prediction in the paper; here `tick` is synchronous but
//! only runs every period, which preserves the accuracy dynamics Fig. 14
//! measures (see DESIGN.md).

use crate::config::LearningConfig;
use crate::estimator::{BatchShape, ServingTimeEstimator};
use crate::logdb::LogDb;
use crate::predictor::GenLenPredictor;
use crate::workload::TraceSource;

/// Sweeps the log DB and retrains the two learned components.
///
/// Sweeps are incremental: each keeps an append-index cursor into the
/// log DB (entries arrive in completion-time order), so a sweep touches
/// only the entries logged since the previous one — O(new) per sweep
/// instead of rescanning the whole log, and the refits they trigger are
/// themselves incremental appends.  The log DB is segmented, so the
/// sealed history a sweep consumes is read without holding any lock —
/// in the live server a learner pass no longer stalls worker logging
/// (only the final ≤ one-segment tail is visited under the append lock),
/// and the predictor sweep (requests table) and estimator sweep (batches
/// table) never contend with each other.
pub struct ContinuousLearner {
    cfg: LearningConfig,
    last_pred_sweep: f64,
    last_est_sweep: f64,
    pred_cursor: usize,
    est_cursor: usize,
    /// Telemetry: (time, #collected) per sweep.
    pub predictor_sweeps: Vec<(f64, usize)>,
    pub estimator_sweeps: Vec<(f64, usize)>,
}

impl ContinuousLearner {
    pub fn new(cfg: LearningConfig) -> Self {
        ContinuousLearner {
            cfg,
            last_pred_sweep: 0.0,
            last_est_sweep: 0.0,
            pred_cursor: 0,
            est_cursor: 0,
            predictor_sweeps: Vec::new(),
            estimator_sweeps: Vec::new(),
        }
    }

    /// Run any due sweeps at time `now`.  `store` is the run's trace
    /// source (a single store, or a sharded trace that resolves each
    /// meta against its minting shard): log entries carry compact
    /// metas, and the predictor sweep borrows each bad request's text
    /// from the arena (zero-copy) to rebuild its features.
    pub fn tick<S: TraceSource + ?Sized>(
        &mut self,
        now: f64,
        db: &LogDb,
        predictor: &mut GenLenPredictor,
        estimator: &mut ServingTimeEstimator,
        store: &S,
    ) {
        if now - self.last_pred_sweep >= self.cfg.predictor_period_s {
            self.sweep_predictor(now, db, predictor, store);
        }
        if now - self.last_est_sweep >= self.cfg.estimator_period_s {
            self.sweep_estimator(now, db, estimator);
        }
    }

    /// §III-B: collect requests with |err| > 10 tokens AND > 10% of the
    /// actual generation length; augment + refit.  Only the log tail
    /// since the previous sweep is visited (cursor-indexed), and bad
    /// rows are absorbed straight into the predictor's column-major
    /// train set during the visit — the text is borrowed from the trace
    /// arena, no request is cloned — followed by one refit.
    fn sweep_predictor<S: TraceSource + ?Sized>(
        &mut self,
        now: f64,
        db: &LogDb,
        predictor: &mut GenLenPredictor,
        store: &S,
    ) {
        self.last_pred_sweep = now;
        let (err_tokens, err_frac) =
            (self.cfg.predictor_err_tokens, self.cfg.predictor_err_frac);
        let mut n_bad = 0usize;
        let visited = db.visit_requests_from(self.pred_cursor, |l| {
            let err = (l.predicted_gen_len as f64 - l.actual_gen_len as f64).abs();
            if err > err_tokens && err > err_frac * l.actual_gen_len as f64 {
                n_bad += 1;
                predictor.absorb(store.view_of(&l.meta));
            }
        });
        self.pred_cursor += visited;
        self.predictor_sweeps.push((now, n_bad));
        if n_bad > 0 {
            predictor.refit();
        }
    }

    /// §III-D: collect batches with |err| > 2 s AND > 20% of the actual
    /// serving time; augment + refit.  Per the paper the batch is
    /// "re-predicted with the actual generation length" before the error
    /// test — the logged shape already carries the actual G(B).
    fn sweep_estimator(&mut self, now: f64, db: &LogDb, estimator: &mut ServingTimeEstimator) {
        self.last_est_sweep = now;
        let (err_s, err_frac) = (self.cfg.estimator_err_s, self.cfg.estimator_err_frac);
        let mut bad: Vec<(BatchShape, f64)> = Vec::new();
        let visited = db.visit_batches_from(self.est_cursor, |l| {
            let repredicted = estimator.estimate(&l.shape);
            let err = (repredicted - l.actual_time).abs();
            if err > err_s && err > err_frac * l.actual_time {
                bad.push((l.shape, l.actual_time));
            }
        });
        self.est_cursor += visited;
        self.estimator_sweeps.push((now, bad.len()));
        if !bad.is_empty() {
            let shapes: Vec<BatchShape> = bad.iter().map(|b| b.0).collect();
            let times: Vec<f64> = bad.iter().map(|b| b.1).collect();
            estimator.augment_and_refit(&shapes, &times);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::logdb::{BatchLog, RequestLog};
    use crate::predictor::Variant;
    use crate::workload::dataset::build_predictor_split;
    use crate::workload::{LlmProfile, TraceStore};

    fn learner(pred_period: f64, est_period: f64) -> ContinuousLearner {
        ContinuousLearner::new(LearningConfig {
            predictor_period_s: pred_period,
            estimator_period_s: est_period,
            ..Default::default()
        })
    }

    #[test]
    fn predictor_sweep_collects_only_bad_predictions() {
        let cfg = ServingConfig::default();
        let db = LogDb::new();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 30, 10, 1024, 20);
        let store = TraceStore::from_requests(&split.train);
        // one bad (err 50 > 10 and > 10%), one good (err 0)
        db.log_request(RequestLog {
            meta: store.meta(0),
            predicted_gen_len: split.train[0].gen_len + 50,
            actual_gen_len: split.train[0].gen_len,
            at: 100.0,
        });
        db.log_request(RequestLog {
            meta: store.meta(1),
            predicted_gen_len: split.train[1].gen_len,
            actual_gen_len: split.train[1].gen_len,
            at: 110.0,
        });
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let n0 = p.train_size();
        let mut est = ServingTimeEstimator::new(3);
        let mut l = learner(180.0, 1e18);
        l.tick(200.0, &db, &mut p, &mut est, &store);
        assert_eq!(l.predictor_sweeps.len(), 1);
        assert_eq!(l.predictor_sweeps[0].1, 1);
        assert_eq!(p.train_size(), n0 + 1);
    }

    #[test]
    fn estimator_sweep_thresholds() {
        let cfg = ServingConfig::default();
        let db = LogDb::new();
        let shape = BatchShape {
            batch_size: 4,
            batch_len: 100,
            batch_gen_len: 100,
        };
        // actual 30s vs cold-start estimate 6s → err 24s > 2s and > 20%
        db.log_batch(BatchLog {
            shape,
            estimated_time: 6.0,
            actual_time: 30.0,
            at: 50.0,
        });
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 10, 2, 1024, 21);
        let mut p = GenLenPredictor::new(Variant::Uilo, &cfg);
        let mut est = ServingTimeEstimator::new(3);
        let mut l = learner(1e18, 120.0);
        l.tick(121.0, &db, &mut p, &mut est, &TraceStore::new());
        assert_eq!(l.estimator_sweeps.len(), 1);
        assert_eq!(l.estimator_sweeps[0].1, 1);
        assert!(est.is_trained());
        // now the estimator knows this region
        assert!((est.estimate(&shape) - 30.0).abs() < 1.0);
        let _ = split;
    }

    #[test]
    fn sweeps_never_revisit_old_entries() {
        // The same bad log entry must be collected exactly once across
        // sweeps (cursor-indexed tails, not time-window rescans).
        let cfg = ServingConfig::default();
        let db = LogDb::new();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 30, 10, 1024, 23);
        let store = TraceStore::from_requests(&split.train);
        db.log_request(RequestLog {
            meta: store.meta(0),
            predicted_gen_len: split.train[0].gen_len + 50,
            actual_gen_len: split.train[0].gen_len,
            at: 100.0,
        });
        let mut p = GenLenPredictor::new(Variant::Usin, &cfg);
        p.train(&split.train);
        let mut est = ServingTimeEstimator::new(3);
        let mut l = learner(100.0, 1e18);
        l.tick(150.0, &db, &mut p, &mut est, &store);
        assert_eq!(l.predictor_sweeps[0].1, 1);
        let n1 = p.train_size();
        // second sweep: no new logs → nothing collected, no refit growth
        l.tick(300.0, &db, &mut p, &mut est, &store);
        assert_eq!(l.predictor_sweeps[1].1, 0);
        assert_eq!(p.train_size(), n1);
    }

    #[test]
    fn ticks_respect_periods() {
        let cfg = ServingConfig::default();
        let db = LogDb::new();
        let split = build_predictor_split(LlmProfile::ChatGlm6B, 10, 2, 1024, 22);
        let mut p = GenLenPredictor::new(Variant::Uilo, &cfg);
        let mut est = ServingTimeEstimator::new(3);
        let mut l = learner(180.0, 120.0);
        let store = TraceStore::new();
        for t in [10.0, 50.0, 100.0] {
            l.tick(t, &db, &mut p, &mut est, &store);
        }
        assert_eq!(l.predictor_sweeps.len(), 0);
        assert_eq!(l.estimator_sweeps.len(), 0);
        l.tick(185.0, &db, &mut p, &mut est, &store);
        assert_eq!(l.predictor_sweeps.len(), 1);
        assert_eq!(l.estimator_sweeps.len(), 1);
        let _ = split;
    }
}
