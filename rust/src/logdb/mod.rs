//! In-memory log database (the paper's Redis substitute, §III-F).
//!
//! Worker processes append execution logs here; the continuous-learning
//! loops (§III-B predictor every 3 min, §III-D estimator every 2 min) read
//! back entries newer than their last sweep.  Thread-safe so the live
//! server's workers can log concurrently; `snapshot`/`restore` provide the
//! "persist periodically" behaviour.

use std::sync::Mutex;

use crate::estimator::BatchShape;
use crate::util::Json;
use crate::workload::Request;

/// A served request log entry (feeds predictor continuous learning).
#[derive(Debug, Clone)]
pub struct RequestLog {
    pub request: Request,
    pub predicted_gen_len: u32,
    pub actual_gen_len: u32,
    /// Completion (sim or wall) time.
    pub at: f64,
}

/// A served batch log entry (feeds estimator continuous learning).
#[derive(Debug, Clone)]
pub struct BatchLog {
    /// Shape with the ACTUAL batch generation length.
    pub shape: BatchShape,
    /// What the estimator had predicted before serving.
    pub estimated_time: f64,
    pub actual_time: f64,
    pub at: f64,
}

#[derive(Debug, Default)]
struct Inner {
    requests: Vec<RequestLog>,
    batches: Vec<BatchLog>,
}

/// Thread-safe log store.
#[derive(Debug, Default)]
pub struct LogDb {
    inner: Mutex<Inner>,
}

impl LogDb {
    pub fn new() -> Self {
        LogDb::default()
    }

    pub fn log_request(&self, entry: RequestLog) {
        self.inner.lock().unwrap().requests.push(entry);
    }

    pub fn log_batch(&self, entry: BatchLog) {
        self.inner.lock().unwrap().batches.push(entry);
    }

    /// Request logs with `at` in (since, until].
    pub fn requests_between(&self, since: f64, until: f64) -> Vec<RequestLog> {
        self.inner
            .lock()
            .unwrap()
            .requests
            .iter()
            .filter(|r| r.at > since && r.at <= until)
            .cloned()
            .collect()
    }

    /// Batch logs with `at` in (since, until].
    pub fn batches_between(&self, since: f64, until: f64) -> Vec<BatchLog> {
        self.inner
            .lock()
            .unwrap()
            .batches
            .iter()
            .filter(|b| b.at > since && b.at <= until)
            .cloned()
            .collect()
    }

    pub fn n_requests(&self) -> usize {
        self.inner.lock().unwrap().requests.len()
    }

    pub fn n_batches(&self) -> usize {
        self.inner.lock().unwrap().batches.len()
    }

    /// Periodic persistence: serialise batch logs (request text omitted —
    /// shapes and errors are what retraining needs at restore time).
    pub fn snapshot(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        Json::obj(vec![(
            "batches",
            Json::Arr(
                inner
                    .batches
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("beta", Json::num(b.shape.batch_size as f64)),
                            ("len", Json::num(b.shape.batch_len as f64)),
                            ("gen", Json::num(b.shape.batch_gen_len as f64)),
                            ("est", Json::num(b.estimated_time)),
                            ("act", Json::num(b.actual_time)),
                            ("at", Json::num(b.at)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    pub fn restore(&self, j: &Json) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(arr) = j.get("batches").as_arr() {
            for item in arr {
                inner.batches.push(BatchLog {
                    shape: BatchShape {
                        batch_size: item.get("beta").as_u64().unwrap_or(1) as u32,
                        batch_len: item.get("len").as_u64().unwrap_or(1) as u32,
                        batch_gen_len: item.get("gen").as_u64().unwrap_or(1) as u32,
                    },
                    estimated_time: item.get("est").as_f64().unwrap_or(0.0),
                    actual_time: item.get("act").as_f64().unwrap_or(0.0),
                    at: item.get("at").as_f64().unwrap_or(0.0),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    fn rlog(at: f64) -> RequestLog {
        RequestLog {
            request: Request {
                id: 0,
                task: TaskId::Gc,
                instruction: String::new(),
                user_input: String::new(),
                user_input_len: 5,
                request_len: 6,
                gen_len: 7,
                arrival: 0.0,
            },
            predicted_gen_len: 9,
            actual_gen_len: 7,
            at,
        }
    }

    fn blog(at: f64) -> BatchLog {
        BatchLog {
            shape: BatchShape {
                batch_size: 4,
                batch_len: 100,
                batch_gen_len: 50,
            },
            estimated_time: 2.0,
            actual_time: 3.0,
            at,
        }
    }

    #[test]
    fn window_queries() {
        let db = LogDb::new();
        for t in [1.0, 2.0, 3.0, 4.0] {
            db.log_request(rlog(t));
            db.log_batch(blog(t));
        }
        assert_eq!(db.requests_between(1.0, 3.0).len(), 2); // (1,3] → 2,3
        assert_eq!(db.batches_between(0.0, 10.0).len(), 4);
        assert_eq!(db.requests_between(4.0, 9.0).len(), 0);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let db = LogDb::new();
        db.log_batch(blog(1.5));
        db.log_batch(blog(2.5));
        let snap = db.snapshot();
        let db2 = LogDb::new();
        db2.restore(&Json::parse(&snap.to_string()).unwrap());
        assert_eq!(db2.n_batches(), 2);
        let got = db2.batches_between(0.0, 10.0);
        assert_eq!(got[0].shape.batch_size, 4);
        assert_eq!(got[1].actual_time, 3.0);
    }

    #[test]
    fn concurrent_logging() {
        use std::sync::Arc;
        let db = Arc::new(LogDb::new());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let db = db.clone();
                std::thread::spawn(move || {
                    for j in 0..100 {
                        db.log_request(rlog(i as f64 + j as f64));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(db.n_requests(), 800);
    }
}
