//! In-memory log database (the paper's Redis substitute, §III-F).
//!
//! Worker processes append execution logs here; the continuous-learning
//! loops (§III-B predictor every 3 min, §III-D estimator every 2 min) read
//! back entries newer than their last sweep.  Thread-safe so the live
//! server's workers can log concurrently; `snapshot`/`restore` provide the
//! "persist periodically" behaviour.

use std::sync::Mutex;

use crate::estimator::BatchShape;
use crate::util::Json;
use crate::workload::Request;

/// A served request log entry (feeds predictor continuous learning).
#[derive(Debug, Clone)]
pub struct RequestLog {
    pub request: Request,
    pub predicted_gen_len: u32,
    pub actual_gen_len: u32,
    /// Completion (sim or wall) time.
    pub at: f64,
}

/// A served batch log entry (feeds estimator continuous learning).
#[derive(Debug, Clone)]
pub struct BatchLog {
    /// Shape with the ACTUAL batch generation length.
    pub shape: BatchShape,
    /// What the estimator had predicted before serving.
    pub estimated_time: f64,
    pub actual_time: f64,
    pub at: f64,
}

#[derive(Debug, Default)]
struct Inner {
    requests: Vec<RequestLog>,
    batches: Vec<BatchLog>,
}

/// Thread-safe log store.
#[derive(Debug, Default)]
pub struct LogDb {
    inner: Mutex<Inner>,
}

impl LogDb {
    pub fn new() -> Self {
        LogDb::default()
    }

    pub fn log_request(&self, entry: RequestLog) {
        self.inner.lock().unwrap().requests.push(entry);
    }

    pub fn log_batch(&self, entry: BatchLog) {
        self.inner.lock().unwrap().batches.push(entry);
    }

    /// Request logs with `at` in (since, until].
    pub fn requests_between(&self, since: f64, until: f64) -> Vec<RequestLog> {
        self.inner
            .lock()
            .unwrap()
            .requests
            .iter()
            .filter(|r| r.at > since && r.at <= until)
            .cloned()
            .collect()
    }

    /// Batch logs with `at` in (since, until].
    pub fn batches_between(&self, since: f64, until: f64) -> Vec<BatchLog> {
        self.inner
            .lock()
            .unwrap()
            .batches
            .iter()
            .filter(|b| b.at > since && b.at <= until)
            .cloned()
            .collect()
    }

    /// Visit request logs from append index `from` onward; returns how
    /// many were visited so the caller can advance a cursor.
    ///
    /// Entries are appended in completion order (nondecreasing `at`), so
    /// an index cursor replaces the O(total-log) time-window scans the
    /// continuous-learning sweeps used to do — each sweep now costs
    /// O(new entries), O(n) cumulative over a run instead of O(n²).
    pub fn visit_requests_from<F: FnMut(&RequestLog)>(&self, from: usize, mut f: F) -> usize {
        let inner = self.inner.lock().unwrap();
        let tail = &inner.requests[from.min(inner.requests.len())..];
        for entry in tail {
            f(entry);
        }
        tail.len()
    }

    /// Visit batch logs from append index `from` onward; returns how many
    /// were visited (see [`LogDb::visit_requests_from`]).
    pub fn visit_batches_from<F: FnMut(&BatchLog)>(&self, from: usize, mut f: F) -> usize {
        let inner = self.inner.lock().unwrap();
        let tail = &inner.batches[from.min(inner.batches.len())..];
        for entry in tail {
            f(entry);
        }
        tail.len()
    }

    pub fn n_requests(&self) -> usize {
        self.inner.lock().unwrap().requests.len()
    }

    pub fn n_batches(&self) -> usize {
        self.inner.lock().unwrap().batches.len()
    }

    /// Periodic persistence: serialise batch logs (request text omitted —
    /// shapes and errors are what retraining needs at restore time).
    pub fn snapshot(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        Json::obj(vec![(
            "batches",
            Json::Arr(
                inner
                    .batches
                    .iter()
                    .map(|b| {
                        Json::obj(vec![
                            ("beta", Json::num(b.shape.batch_size as f64)),
                            ("len", Json::num(b.shape.batch_len as f64)),
                            ("gen", Json::num(b.shape.batch_gen_len as f64)),
                            ("est", Json::num(b.estimated_time)),
                            ("act", Json::num(b.actual_time)),
                            ("at", Json::num(b.at)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    pub fn restore(&self, j: &Json) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(arr) = j.get("batches").as_arr() {
            for item in arr {
                inner.batches.push(BatchLog {
                    shape: BatchShape {
                        batch_size: item.get("beta").as_u64().unwrap_or(1) as u32,
                        batch_len: item.get("len").as_u64().unwrap_or(1) as u32,
                        batch_gen_len: item.get("gen").as_u64().unwrap_or(1) as u32,
                    },
                    estimated_time: item.get("est").as_f64().unwrap_or(0.0),
                    actual_time: item.get("act").as_f64().unwrap_or(0.0),
                    at: item.get("at").as_f64().unwrap_or(0.0),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    fn rlog(at: f64) -> RequestLog {
        RequestLog {
            request: Request {
                id: 0,
                task: TaskId::Gc,
                instruction: String::new(),
                user_input: String::new(),
                user_input_len: 5,
                request_len: 6,
                gen_len: 7,
                arrival: 0.0,
            },
            predicted_gen_len: 9,
            actual_gen_len: 7,
            at,
        }
    }

    fn blog(at: f64) -> BatchLog {
        BatchLog {
            shape: BatchShape {
                batch_size: 4,
                batch_len: 100,
                batch_gen_len: 50,
            },
            estimated_time: 2.0,
            actual_time: 3.0,
            at,
        }
    }

    #[test]
    fn window_queries() {
        let db = LogDb::new();
        for t in [1.0, 2.0, 3.0, 4.0] {
            db.log_request(rlog(t));
            db.log_batch(blog(t));
        }
        assert_eq!(db.requests_between(1.0, 3.0).len(), 2); // (1,3] → 2,3
        assert_eq!(db.batches_between(0.0, 10.0).len(), 4);
        assert_eq!(db.requests_between(4.0, 9.0).len(), 0);
    }

    #[test]
    fn cursor_visits_only_the_tail() {
        let db = LogDb::new();
        for t in [1.0, 2.0, 3.0] {
            db.log_request(rlog(t));
            db.log_batch(blog(t));
        }
        let mut cursor = 0usize;
        let mut seen = Vec::new();
        cursor += db.visit_requests_from(cursor, |r| seen.push(r.at));
        assert_eq!((cursor, seen.as_slice()), (3, &[1.0, 2.0, 3.0][..]));
        // nothing new → no visits
        assert_eq!(db.visit_requests_from(cursor, |_| panic!("no tail")), 0);
        db.log_request(rlog(4.0));
        let mut tail = Vec::new();
        cursor += db.visit_requests_from(cursor, |r| tail.push(r.at));
        assert_eq!((cursor, tail.as_slice()), (4, &[4.0][..]));
        // past-the-end cursor is safe
        assert_eq!(db.visit_batches_from(99, |_| panic!("no tail")), 0);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let db = LogDb::new();
        db.log_batch(blog(1.5));
        db.log_batch(blog(2.5));
        let snap = db.snapshot();
        let db2 = LogDb::new();
        db2.restore(&Json::parse(&snap.to_string()).unwrap());
        assert_eq!(db2.n_batches(), 2);
        let got = db2.batches_between(0.0, 10.0);
        assert_eq!(got[0].shape.batch_size, 4);
        assert_eq!(got[1].actual_time, 3.0);
    }

    #[test]
    fn concurrent_logging() {
        use std::sync::Arc;
        let db = Arc::new(LogDb::new());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let db = db.clone();
                std::thread::spawn(move || {
                    for j in 0..100 {
                        db.log_request(rlog(i as f64 + j as f64));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(db.n_requests(), 800);
    }
}
