//! In-memory log database (the paper's Redis substitute, §III-F).
//!
//! Worker processes append execution logs here; the continuous-learning
//! loops (§III-B predictor every 3 min, §III-D estimator every 2 min) read
//! back entries newer than their last sweep.  Thread-safe so the live
//! server's workers can log concurrently; `snapshot`/`restore` provide the
//! "persist periodically" behaviour.
//!
//! ## Segmented storage
//!
//! Each table is an **append-only segment list**: full segments are
//! sealed behind `Arc`s and become immutable, while appends lock only the
//! small open tail.  A learner sweep therefore reads the sealed history
//! entirely lock-free (it snapshots the `Arc` handles and drops the lock
//! before visiting a single entry) and touches the append path only for
//! the final ≤ `SEG_CAP` tail entries — worker logging and learner sweeps
//! no longer serialise against one table-wide mutex, and the two tables
//! (requests, batches) are independent so predictor and estimator sweeps
//! never contend with each other at all.
//!
//! Readers still observe a **consistent prefix**: entries have stable
//! global append indices (segment number × `SEG_CAP` + offset), sealing
//! happens under the tail lock, and `visit_*_from` re-checks the sealed
//! list under that lock before reading the tail, so a cursor sweep sees
//! every entry below its final cursor exactly once, in append order.

use std::sync::{Arc, Mutex, RwLock};

use crate::estimator::BatchShape;
use crate::util::Json;
use crate::workload::RequestMeta;

/// Entries per sealed segment.  Small enough that the tail visit (the
/// only part of a sweep that blocks writers) stays bounded and short;
/// large enough that the sealed list and its `Arc` churn stay tiny.
const SEG_CAP: usize = 256;

/// A served request log entry (feeds predictor continuous learning).
///
/// Carries the compact [`RequestMeta`] — `Copy`, so logging a completion
/// costs a few machine words and no heap traffic.  Consumers that need
/// the request text (the predictor sweep's feature absorption) resolve it
/// through the run's shared `TraceStore`.
#[derive(Debug, Clone, Copy)]
pub struct RequestLog {
    pub meta: RequestMeta,
    pub predicted_gen_len: u32,
    pub actual_gen_len: u32,
    /// Completion (sim or wall) time.
    pub at: f64,
}

/// A served batch log entry (feeds estimator continuous learning).
#[derive(Debug, Clone)]
pub struct BatchLog {
    /// Shape with the ACTUAL batch generation length.
    pub shape: BatchShape,
    /// What the estimator had predicted before serving.
    pub estimated_time: f64,
    pub actual_time: f64,
    pub at: f64,
}

/// One append-only table: sealed immutable segments + an open tail.
///
/// Lock order everywhere is tail → sealed, so a reader holding the tail
/// lock sees a frozen sealed list (sealing needs the tail lock too) and
/// writers can never deadlock against sweeps.
#[derive(Debug)]
struct Table<T> {
    /// Full segments, each exactly `SEG_CAP` entries, immutable forever.
    sealed: RwLock<Vec<Arc<Vec<T>>>>,
    /// The open tail segment; appends lock only this.
    tail: Mutex<Vec<T>>,
}

impl<T> Table<T> {
    fn new() -> Self {
        Table {
            sealed: RwLock::new(Vec::new()),
            tail: Mutex::new(Vec::with_capacity(SEG_CAP)),
        }
    }

    /// Append one entry — O(1), holding only the tail lock (plus a brief
    /// sealed-list write when a segment fills, amortised 1/`SEG_CAP`).
    fn push(&self, entry: T) {
        let mut tail = self.tail.lock().unwrap();
        tail.push(entry);
        if tail.len() == SEG_CAP {
            let seg = Arc::new(std::mem::replace(&mut *tail, Vec::with_capacity(SEG_CAP)));
            self.sealed.write().unwrap().push(seg);
        }
    }

    fn len(&self) -> usize {
        let tail = self.tail.lock().unwrap();
        let sealed = self.sealed.read().unwrap().len();
        sealed * SEG_CAP + tail.len()
    }

    /// Visit entries from global append index `from` onward, in order;
    /// returns how many were visited so the caller can advance a cursor.
    ///
    /// Phase 1 snapshots the sealed `Arc` handles and visits them with
    /// **no lock held**; phase 2 takes the tail lock (freezing sealing),
    /// catches up on any segment sealed mid-sweep, and finishes with the
    /// open tail.
    fn visit_from<F: FnMut(&T)>(&self, from: usize, mut f: F) -> usize {
        let mut cursor = from;
        // Phase 1: lock-free sweep of the sealed history.
        let snapshot: Vec<Arc<Vec<T>>> = {
            let sealed = self.sealed.read().unwrap();
            let first = (cursor / SEG_CAP).min(sealed.len());
            sealed[first..].to_vec() // Arc clones only
        };
        for seg in &snapshot {
            let base = (cursor / SEG_CAP) * SEG_CAP;
            for entry in &seg[cursor - base..] {
                f(entry);
            }
            cursor = base + SEG_CAP;
        }
        // Phase 2: under the tail lock the sealed list is frozen; drain
        // anything sealed since the snapshot, then the tail itself.
        let tail = self.tail.lock().unwrap();
        let sealed = self.sealed.read().unwrap();
        while cursor / SEG_CAP < sealed.len() {
            let s = cursor / SEG_CAP;
            let base = s * SEG_CAP;
            for entry in &sealed[s][cursor - base..] {
                f(entry);
            }
            cursor = base + SEG_CAP;
        }
        let base = sealed.len() * SEG_CAP;
        debug_assert!(cursor >= base || from >= base, "cursor behind the tail");
        if cursor >= base && cursor < base + tail.len() {
            for entry in &tail[cursor - base..] {
                f(entry);
            }
            cursor = base + tail.len();
        }
        cursor.saturating_sub(from)
    }
}

/// Thread-safe log store over two independent segmented tables.
#[derive(Debug)]
pub struct LogDb {
    requests: Table<RequestLog>,
    batches: Table<BatchLog>,
}

impl Default for LogDb {
    fn default() -> Self {
        LogDb {
            requests: Table::new(),
            batches: Table::new(),
        }
    }
}

impl LogDb {
    pub fn new() -> Self {
        LogDb::default()
    }

    pub fn log_request(&self, entry: RequestLog) {
        self.requests.push(entry);
    }

    pub fn log_batch(&self, entry: BatchLog) {
        self.batches.push(entry);
    }

    /// Request logs with `at` in (since, until].
    pub fn requests_between(&self, since: f64, until: f64) -> Vec<RequestLog> {
        let mut out = Vec::new();
        self.requests.visit_from(0, |r| {
            if r.at > since && r.at <= until {
                out.push(r.clone());
            }
        });
        out
    }

    /// Batch logs with `at` in (since, until].
    pub fn batches_between(&self, since: f64, until: f64) -> Vec<BatchLog> {
        let mut out = Vec::new();
        self.batches.visit_from(0, |b| {
            if b.at > since && b.at <= until {
                out.push(b.clone());
            }
        });
        out
    }

    /// Visit request logs from append index `from` onward; returns how
    /// many were visited so the caller can advance a cursor.
    ///
    /// Entries are appended in completion order (nondecreasing `at`), so
    /// an index cursor replaces the O(total-log) time-window scans the
    /// continuous-learning sweeps used to do — each sweep now costs
    /// O(new entries), O(n) cumulative over a run instead of O(n²) —
    /// and the segmented store lets it run concurrently with writers
    /// (see the module docs).
    pub fn visit_requests_from<F: FnMut(&RequestLog)>(&self, from: usize, f: F) -> usize {
        self.requests.visit_from(from, f)
    }

    /// Visit batch logs from append index `from` onward; returns how many
    /// were visited (see [`LogDb::visit_requests_from`]).
    pub fn visit_batches_from<F: FnMut(&BatchLog)>(&self, from: usize, f: F) -> usize {
        self.batches.visit_from(from, f)
    }

    pub fn n_requests(&self) -> usize {
        self.requests.len()
    }

    pub fn n_batches(&self) -> usize {
        self.batches.len()
    }

    /// Periodic persistence: serialise batch logs (request text omitted —
    /// shapes and errors are what retraining needs at restore time).
    pub fn snapshot(&self) -> Json {
        let mut items = Vec::new();
        self.batches.visit_from(0, |b| {
            items.push(Json::obj(vec![
                ("beta", Json::num(b.shape.batch_size as f64)),
                ("len", Json::num(b.shape.batch_len as f64)),
                ("gen", Json::num(b.shape.batch_gen_len as f64)),
                ("est", Json::num(b.estimated_time)),
                ("act", Json::num(b.actual_time)),
                ("at", Json::num(b.at)),
            ]));
        });
        Json::obj(vec![("batches", Json::Arr(items))])
    }

    pub fn restore(&self, j: &Json) {
        if let Some(arr) = j.get("batches").as_arr() {
            for item in arr {
                self.batches.push(BatchLog {
                    shape: BatchShape {
                        batch_size: item.get("beta").as_u64().unwrap_or(1) as u32,
                        batch_len: item.get("len").as_u64().unwrap_or(1) as u32,
                        batch_gen_len: item.get("gen").as_u64().unwrap_or(1) as u32,
                    },
                    estimated_time: item.get("est").as_f64().unwrap_or(0.0),
                    actual_time: item.get("act").as_f64().unwrap_or(0.0),
                    at: item.get("at").as_f64().unwrap_or(0.0),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Span, StoreId, TaskId};

    fn rlog(at: f64) -> RequestLog {
        RequestLog {
            meta: RequestMeta {
                id: 0,
                task: TaskId::Gc,
                store: StoreId::DETACHED,
                instr: u32::MAX,
                user_input_len: 5,
                request_len: 6,
                gen_len: 7,
                arrival: 0.0,
                span: Span::DETACHED,
                uih: 0,
            },
            predicted_gen_len: 9,
            actual_gen_len: 7,
            at,
        }
    }

    fn blog(at: f64) -> BatchLog {
        BatchLog {
            shape: BatchShape {
                batch_size: 4,
                batch_len: 100,
                batch_gen_len: 50,
            },
            estimated_time: 2.0,
            actual_time: 3.0,
            at,
        }
    }

    #[test]
    fn window_queries() {
        let db = LogDb::new();
        for t in [1.0, 2.0, 3.0, 4.0] {
            db.log_request(rlog(t));
            db.log_batch(blog(t));
        }
        assert_eq!(db.requests_between(1.0, 3.0).len(), 2); // (1,3] → 2,3
        assert_eq!(db.batches_between(0.0, 10.0).len(), 4);
        assert_eq!(db.requests_between(4.0, 9.0).len(), 0);
    }

    #[test]
    fn cursor_visits_only_the_tail() {
        let db = LogDb::new();
        for t in [1.0, 2.0, 3.0] {
            db.log_request(rlog(t));
            db.log_batch(blog(t));
        }
        let mut cursor = 0usize;
        let mut seen = Vec::new();
        cursor += db.visit_requests_from(cursor, |r| seen.push(r.at));
        assert_eq!((cursor, seen.as_slice()), (3, &[1.0, 2.0, 3.0][..]));
        // nothing new → no visits
        assert_eq!(db.visit_requests_from(cursor, |_| panic!("no tail")), 0);
        db.log_request(rlog(4.0));
        let mut tail = Vec::new();
        cursor += db.visit_requests_from(cursor, |r| tail.push(r.at));
        assert_eq!((cursor, tail.as_slice()), (4, &[4.0][..]));
        // past-the-end cursor is safe
        assert_eq!(db.visit_batches_from(99, |_| panic!("no tail")), 0);
    }

    #[test]
    fn cursor_sweeps_across_segment_seals() {
        // Appends spanning several sealed segments: a cursor advanced in
        // arbitrary chunks must see every entry exactly once, in order.
        let db = LogDb::new();
        let total = SEG_CAP * 3 + 17;
        let mut cursor = 0usize;
        let mut seen = Vec::new();
        for i in 0..total {
            db.log_request(rlog(i as f64));
            if i % 97 == 0 {
                cursor += db.visit_requests_from(cursor, |r| seen.push(r.at));
            }
        }
        cursor += db.visit_requests_from(cursor, |r| seen.push(r.at));
        assert_eq!(cursor, total);
        assert_eq!(seen.len(), total);
        assert!(seen.iter().enumerate().all(|(i, &at)| at == i as f64));
        assert_eq!(db.n_requests(), total);
        // mid-segment cursors resume correctly
        let mut from_mid = Vec::new();
        let visited = db.visit_requests_from(SEG_CAP + 5, |r| from_mid.push(r.at));
        assert_eq!(visited, total - SEG_CAP - 5);
        assert_eq!(from_mid[0], (SEG_CAP + 5) as f64);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let db = LogDb::new();
        db.log_batch(blog(1.5));
        db.log_batch(blog(2.5));
        let snap = db.snapshot();
        let db2 = LogDb::new();
        db2.restore(&Json::parse(&snap.to_string()).unwrap());
        assert_eq!(db2.n_batches(), 2);
        let got = db2.batches_between(0.0, 10.0);
        assert_eq!(got[0].shape.batch_size, 4);
        assert_eq!(got[1].actual_time, 3.0);
    }

    #[test]
    fn concurrent_logging() {
        use std::sync::Arc;
        let db = Arc::new(LogDb::new());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let db = db.clone();
                std::thread::spawn(move || {
                    for j in 0..100 {
                        db.log_request(rlog(i as f64 + j as f64));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(db.n_requests(), 800);
    }

    /// Satellite smoke test: sweeps running concurrently with writers
    /// observe a consistent prefix — every visited entry is complete, a
    /// cursor never double-visits or skips, and per-writer sequence
    /// numbers arrive in order.
    #[test]
    fn concurrent_sweeps_observe_consistent_prefix() {
        use std::sync::Arc;
        const WRITERS: usize = 4;
        const PER_WRITER: usize = SEG_CAP * 2 + 31; // spans seals
        let db = Arc::new(LogDb::new());
        let writers: Vec<_> = (0..WRITERS)
            .map(|w| {
                let db = db.clone();
                std::thread::spawn(move || {
                    for seq in 0..PER_WRITER {
                        // encode (writer, seq) in `at`
                        db.log_request(rlog((w * 1_000_000 + seq) as f64));
                    }
                })
            })
            .collect();
        // Reader sweeps with a cursor until all entries are seen.
        let mut cursor = 0usize;
        let mut last_seq = [None::<usize>; WRITERS];
        let mut seen = 0usize;
        while seen < WRITERS * PER_WRITER {
            let visited = db.visit_requests_from(cursor, |r| {
                let code = r.at as usize;
                let (w, seq) = (code / 1_000_000, code % 1_000_000);
                assert!(w < WRITERS, "corrupt entry surfaced mid-append");
                // per-writer order is preserved through the shared log
                assert_eq!(seq, last_seq[w].map_or(0, |s| s + 1), "writer {w}");
                last_seq[w] = Some(seq);
            });
            cursor += visited;
            seen += visited;
            if visited == 0 {
                std::thread::yield_now();
            }
        }
        for h in writers {
            h.join().unwrap();
        }
        assert_eq!(cursor, WRITERS * PER_WRITER);
        assert_eq!(db.n_requests(), WRITERS * PER_WRITER);
        // nothing left
        assert_eq!(db.visit_requests_from(cursor, |_| panic!("done")), 0);
    }
}
