//! Interned trace storage: the zero-copy backbone of the request path.
//!
//! A [`TraceStore`] owns all request text of a workload exactly once:
//!
//! * user-input texts live back-to-back in one contiguous byte **arena**
//!   (one `String`), addressed by [`Span`]s;
//! * instruction texts — a handful of distinct strings repeated across
//!   every request of a task — live in a deduplicated side table,
//!   addressed by index (the seed cloned `task.instruction()` into every
//!   single request);
//! * each request is a compact, `Copy` [`RequestMeta`] carrying the
//!   numeric fields plus those two addresses.
//!
//! The serving pipeline moves `RequestMeta` (and
//! [`PredictedRequest`](crate::workload::PredictedRequest)) by value —
//! arrival, batching, dispatch and logging perform **zero per-request
//! heap allocations**; text consumers (predictor features, real-compute
//! tokenization) borrow `&str` slices straight from the arena via
//! [`TraceStore::view_of`].
//!
//! [`StreamingTraceGen`] generates workloads **into** the store: each
//! request's text is synthesised at its final arena address
//! (`apps::synth_input_into`), so a million-request trace never exists as
//! a `Vec<Request>` of owned strings.  The stream is RNG-for-RNG and
//! byte-for-byte identical to the owned
//! [`generate_trace`](crate::workload::generate_trace) — property-tested
//! in `tests/store_equivalence.rs`.
//!
//! The owned [`Request`] remains the interchange form: JSON round-trips
//! ([`TraceStore::to_json`] emits the exact schema `trace_to_json` always
//! did — task id, never instruction text) and the golden-equivalence
//! reference (`sim::reference`) materialise through
//! [`TraceStore::request_of`] / [`TraceStore::to_requests`].
//!
//! ## Binary trace format (version 1)
//!
//! Replayed traces additionally (de)serialise through a versioned
//! single-file binary layout, so opening a million-request trace is
//! O(metas) — the text arena is **not** parsed, allocated or copied; the
//! store maps the file read-only ([`TraceStore::open_mmap`], raw `mmap`
//! behind [`crate::util::mmap`]) and the kernel pages text in on demand.
//! Multiple server processes replaying the same trace share one
//! read-only mapping.  [`TraceStore::open_read`] is the same decode over
//! bytes read into memory (non-mmap platforms and the differential
//! tests), so both backings run one code route.
//!
//! ```text
//! offset  size       field
//! 0       8          magic  "MAGNUSTR"                 (TRACE_MAGIC)
//! 8       4          format version, u32 LE            (TRACE_VERSION)
//! 12      4          reserved, must be 0
//! 16      8          n_metas, u64 LE
//! 24      8          n_instructions, u64 LE
//! 32      8          instruction-table bytes, u64 LE
//! 40      8          arena bytes, u64 LE
//! 48      n_metas×48 meta table: fixed-width records
//!                    (id u64 | arrival f64-bits | span.start u64 |
//!                     span.len u32 | task u32 | instr u32 | uil u32 |
//!                     request_len u32 | gen_len u32, all LE)
//! …       …          instruction table: per entry u32 LE length + UTF-8
//! …       …          arena: raw UTF-8 user-input text, back to back
//! ```
//!
//! Opening a trace is **O(1) in the meta count**: decode validates the
//! magic, version and section sizes against the file length (checked
//! arithmetic) and parses the tiny instruction table, but the meta
//! records stay on disk behind an alignment-checked in-place view
//! ([`RawMeta`]) and the arena is not scanned — no `Vec<RequestMeta>`
//! materialises and no per-meta `uih` hash runs at open.  Per-meta
//! work (span bounds, UTF-8 of the resolved span, content hashing) is
//! deferred to first access, or to the one-shot [`TraceStore::validate_all`]
//! sweep that tools and the corrupt-input tests
//! (`tests/trace_io.rs`) run over untrusted files: it rejects every
//! corruption the old eager decode did — bad task ids, out-of-range
//! instruction indices, spans past or splitting the arena's UTF-8 —
//! with errors, never panics.  Accessing a corrupt record *without*
//! validating first fails loudly (a panic naming the corruption), never
//! by aliasing text.  Loaded metas are stamped with the fresh store's
//! provenance id like any other minted meta, lazily at access time.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, OnceLock};

use crate::tokenizer::Tokenizer;
use crate::util::mmap::{map_file, read_file, FileBytes};
use crate::util::{Json, Rng};
use crate::workload::apps::{sample_shape, synth_input_into, TaskId};
use crate::workload::request::{
    hash_user_input, hash_user_input_bytes, Request, RequestMeta, RequestView, Span, StoreId,
};
use crate::workload::trace::TraceSpec;

/// Magic bytes opening every binary trace file.
pub const TRACE_MAGIC: [u8; 8] = *b"MAGNUSTR";
/// Binary trace format version this build writes and reads.
pub const TRACE_VERSION: u32 = 1;
/// Fixed size of the binary trace header.
pub const TRACE_HEADER_BYTES: usize = 48;
/// Fixed wire size of one meta record in the binary trace format.
pub const TRACE_META_BYTES: usize = 48;

/// The request-text arena: either owned bytes (generated / interned /
/// JSON-loaded stores) or a validated region of a file opened through
/// the binary trace format (mapped, or read into memory on the fallback
/// route).  Every consumer goes through [`Arena::as_str`], so the
/// serving pipeline is oblivious to the backing.
#[derive(Debug, Clone)]
enum Arena {
    /// Heap-owned text, append-grown by interning and the streaming
    /// generator.
    Owned(String),
    /// Immutable `[offset, offset + len)` region of an opened trace
    /// file.  The `Arc` keeps the backing bytes (and any mmap) alive
    /// across store clones and `Arc<TraceStore>` sharing.
    File {
        bytes: Arc<FileBytes>,
        offset: usize,
        len: usize,
        /// Whether the whole region has been proven UTF-8 (set by
        /// [`TraceStore::validate_all`] or the first whole-arena
        /// access; shared across clones — validity is a property of
        /// the bytes).  Until then each span access validates just its
        /// own bytes, keeping resolution O(span) and open O(1).
        utf8_ok: Arc<AtomicBool>,
    },
}

impl Arena {
    /// The raw arena bytes (no UTF-8 claim).
    #[inline]
    fn raw(&self) -> &[u8] {
        match self {
            Arena::Owned(s) => s.as_bytes(),
            Arena::File { bytes, offset, len, .. } => &bytes[*offset..*offset + *len],
        }
    }

    /// Resolve `[start, start + len)` as text.  Owned arenas are valid
    /// by construction; file arenas validate the requested span alone
    /// (until a full sweep marks the whole region valid), so opening a
    /// file never scans the arena and resolving one request reads one
    /// span.  A span that is out of bounds or not UTF-8 — possible only
    /// on a corrupt file that was never [`TraceStore::validate_all`]ed —
    /// panics with the corruption named, and never aliases text.
    #[inline]
    fn slice(&self, start: usize, len: usize) -> &str {
        match self {
            Arena::Owned(s) => &s[start..start + len],
            Arena::File { utf8_ok, .. } => {
                let end = start
                    .checked_add(len)
                    .expect("corrupt trace: meta span overflows the arena");
                let b = self
                    .raw()
                    .get(start..end)
                    .expect("corrupt trace: meta span out of arena bounds (validate_all rejects this)");
                if utf8_ok.load(Ordering::Relaxed) {
                    // SAFETY: a full sweep (`validate_all` / `as_str`)
                    // proved the whole region — hence every subrange we
                    // hand out, whose ends it checked as char
                    // boundaries — valid UTF-8.  For mapped files this
                    // additionally rests on the trace file not being
                    // modified while mapped — `util::mmap`'s documented
                    // precondition (trace files are write-once).
                    unsafe { std::str::from_utf8_unchecked(b) }
                } else {
                    std::str::from_utf8(b)
                        .expect("corrupt trace: meta span is not UTF-8 (validate_all rejects this)")
                }
            }
        }
    }

    /// The whole arena as one `&str`, running (and memoising) the full
    /// UTF-8 sweep on first use for file-backed arenas.
    #[inline]
    fn as_str(&self) -> &str {
        match self {
            Arena::Owned(s) => s,
            Arena::File { utf8_ok, .. } => {
                let b = self.raw();
                if !utf8_ok.load(Ordering::Relaxed) {
                    std::str::from_utf8(b)
                        .expect("corrupt trace: text arena is not UTF-8 (validate_all rejects this)");
                    utf8_ok.store(true, Ordering::Relaxed);
                }
                // SAFETY: the sweep above (or an earlier one) validated
                // exactly these bytes; see `slice` for the mapped-file
                // immutability precondition.
                unsafe { std::str::from_utf8_unchecked(b) }
            }
        }
    }

    #[inline]
    fn len(&self) -> usize {
        match self {
            Arena::Owned(s) => s.len(),
            Arena::File { len, .. } => *len,
        }
    }

    /// The append target for interning/generation.  File-backed arenas
    /// are immutable by construction — writing into one is a logic
    /// error, not a recoverable condition.
    #[inline]
    fn owned_mut(&mut self) -> &mut String {
        match self {
            Arena::Owned(s) => s,
            Arena::File { .. } => {
                panic!("TraceStore: cannot intern text into a file-backed arena")
            }
        }
    }
}

/// The wire layout of one 48-byte meta record, field for field — the
/// alignment-checked zero-copy view over the on-disk meta table.  All
/// fields are plain little-endian integers on the wire, so on a
/// little-endian target an 8-aligned record can be read **in place**
/// with one typed copy; misaligned buffers (an owned `Vec<u8>` has no
/// alignment guarantee) and big-endian targets take the per-field
/// byte-decode fallback.  Both routes produce identical values —
/// unit-tested below.
#[repr(C)]
#[derive(Clone, Copy)]
struct RawMeta {
    id: u64,
    arrival_bits: u64,
    span_start: u64,
    span_len: u32,
    task: u32,
    instr: u32,
    uil: u32,
    request_len: u32,
    gen_len: u32,
}

// The typed in-place read is sound only while the struct matches the
// wire record exactly (size, alignment, and — via repr(C) declaration
// order — every field offset).
const _: () = assert!(std::mem::size_of::<RawMeta>() == TRACE_META_BYTES);
const _: () = assert!(std::mem::align_of::<RawMeta>() == 8);

/// Read wire record `i` of the meta table starting at
/// [`TRACE_HEADER_BYTES`].  `aligned` is the decode-time alignment
/// check; it gates the typed in-place read (little-endian targets
/// only).  Bounds are the caller's contract (`i < n` from the
/// validated header).
#[inline]
fn wire_meta(b: &[u8], i: usize, aligned: bool) -> RawMeta {
    let off = TRACE_HEADER_BYTES + i * TRACE_META_BYTES;
    let r = &b[off..off + TRACE_META_BYTES];
    #[cfg(target_endian = "little")]
    if aligned {
        // SAFETY: `r` is exactly size_of::<RawMeta>() bytes, 8-aligned
        // (checked once at decode: the table offset is 48, so record
        // alignment is the buffer alignment), and every field of
        // RawMeta is a plain integer — any bit pattern is a value.
        return unsafe { (r.as_ptr() as *const RawMeta).read() };
    }
    #[cfg(not(target_endian = "little"))]
    let _ = aligned;
    RawMeta {
        id: rd_u64(r, 0),
        arrival_bits: rd_u64(r, 8),
        span_start: rd_u64(r, 16),
        span_len: rd_u32(r, 24),
        task: rd_u32(r, 28),
        instr: rd_u32(r, 32),
        uil: rd_u32(r, 36),
        request_len: rd_u32(r, 40),
        gen_len: rd_u32(r, 44),
    }
}

/// The per-request records: materialised for built/parsed stores, or
/// left **in place** on the opened file for binary traces (the
/// tentpole of the O(1) open — a 10⁷-request `.mtr` opens without a
/// 10⁷-element `Vec<RequestMeta>` or 10⁷ content hashes).
#[derive(Debug, Clone)]
enum MetaTable {
    /// Records built in memory (generation, interning, JSON parse).
    Owned(Vec<RequestMeta>),
    /// Records read in place from an opened trace file.
    File {
        /// The whole file (same `Arc` the arena holds).
        bytes: Arc<FileBytes>,
        /// Records visible through this store — ≤ the count on the
        /// wire ([`TraceStore::prefix`] clamps it; section offsets do
        /// not move).
        n: usize,
        /// Byte offset of the instruction table (for byte-exact
        /// re-serialisation without touching the meta records).
        instr_off: usize,
        /// Decode-time alignment check result gating the typed
        /// in-place read ([`wire_meta`]).
        aligned: bool,
        /// Lazily materialised copy backing [`TraceStore::metas`] —
        /// the slice-compat / test path, never required for serving.
        cache: Arc<OnceLock<Vec<RequestMeta>>>,
    },
}

impl MetaTable {
    /// The append target for interning/generation; file-backed tables
    /// are immutable by construction (same contract as
    /// [`Arena::owned_mut`]).
    #[inline]
    fn owned_mut(&mut self) -> &mut Vec<RequestMeta> {
        match self {
            MetaTable::Owned(v) => v,
            MetaTable::File { .. } => {
                panic!("TraceStore: cannot record metas into a file-backed table")
            }
        }
    }
}

/// All text of a workload trace, interned once, plus the compact
/// per-request records addressing it.
#[derive(Debug, Clone)]
pub struct TraceStore {
    /// Provenance stamp minted at construction and carried by every meta
    /// this store records (clones share it — they are true copies, so
    /// resolution against a clone is valid).
    store_id: StoreId,
    /// Every request's user-input text, back to back (owned or a
    /// region of an opened trace file).
    arena: Arena,
    /// Deduplicated instruction texts (typically one per task).
    instructions: Vec<String>,
    /// Compact per-request records, in trace order (owned, or in place
    /// on the opened file).
    metas: MetaTable,
}

impl Default for TraceStore {
    fn default() -> TraceStore {
        TraceStore::new()
    }
}

impl TraceStore {
    pub fn new() -> TraceStore {
        TraceStore {
            store_id: StoreId::mint(),
            arena: Arena::Owned(String::new()),
            instructions: Vec::new(),
            metas: MetaTable::Owned(Vec::new()),
        }
    }

    /// Store with pre-sized buffers (`arena_bytes` is a hint, not a cap).
    pub fn with_capacity(n_requests: usize, arena_bytes: usize) -> TraceStore {
        TraceStore {
            store_id: StoreId::mint(),
            arena: Arena::Owned(String::with_capacity(arena_bytes)),
            instructions: Vec::new(),
            metas: MetaTable::Owned(Vec::with_capacity(n_requests)),
        }
    }

    /// This store's provenance stamp (every meta it records carries it).
    #[inline]
    pub fn id(&self) -> StoreId {
        self.store_id
    }

    /// Loud half of the provenance stamp: a meta minted by a *different*
    /// live store must never resolve text here — a wrong-store span that
    /// happens to be in range would silently alias this store's arena.
    /// Debug-only (the serving hot path resolves millions of spans).
    #[inline]
    fn check_provenance(&self, m: &RequestMeta) {
        debug_assert!(
            m.store == self.store_id,
            "RequestMeta provenance violation: meta {} was minted by store {:?} \
             but is being resolved against store {:?}",
            m.id,
            m.store,
            self.store_id
        );
    }

    /// Index of `instruction` in the dedup table, interning it if new.
    /// Linear probe: the table holds a handful of distinct entries.
    fn intern_instruction(&mut self, instruction: &str) -> u32 {
        if let Some(i) = self.instructions.iter().position(|s| s == instruction) {
            return i as u32;
        }
        self.instructions.push(instruction.to_string());
        (self.instructions.len() - 1) as u32
    }

    /// Record the meta for a request whose user-input text was just
    /// appended to the arena starting at byte `start` — the single place
    /// the span/meta bookkeeping invariant lives (shared by [`Self::push`]
    /// and the streaming generator, which writes text into the arena
    /// directly).
    #[allow(clippy::too_many_arguments)]
    fn record_meta(
        &mut self,
        id: u64,
        task: TaskId,
        instr: u32,
        user_input_len: u32,
        request_len: u32,
        gen_len: u32,
        arrival: f64,
        start: u64,
    ) -> RequestMeta {
        let len = (self.arena.len() as u64 - start) as u32;
        // Hash the just-appended text once, at intern time — every
        // downstream consumer (feature cache, drift keying) reads the
        // stored hash instead of re-walking the text per predict.
        let uih = hash_user_input(&self.arena.as_str()[start as usize..]);
        let meta = RequestMeta {
            id,
            task,
            store: self.store_id,
            instr,
            user_input_len,
            request_len,
            gen_len,
            arrival,
            span: Span { start, len },
            uih,
        };
        self.metas.owned_mut().push(meta);
        meta
    }

    /// Intern one request: the instruction is deduplicated, the user input
    /// appended to the arena, and the returned meta (also recorded in the
    /// store) addresses both.
    #[allow(clippy::too_many_arguments)]
    pub fn push(
        &mut self,
        id: u64,
        task: TaskId,
        instruction: &str,
        user_input: &str,
        user_input_len: u32,
        request_len: u32,
        gen_len: u32,
        arrival: f64,
    ) -> RequestMeta {
        let instr = self.intern_instruction(instruction);
        let start = self.arena.len() as u64;
        self.arena.owned_mut().push_str(user_input);
        self.record_meta(
            id,
            task,
            instr,
            user_input_len,
            request_len,
            gen_len,
            arrival,
            start,
        )
    }

    /// Intern an owned request (text copied into the arena once).
    pub fn push_request(&mut self, r: &Request) -> RequestMeta {
        self.push(
            r.id,
            r.task,
            &r.instruction,
            &r.user_input,
            r.user_input_len,
            r.request_len,
            r.gen_len,
            r.arrival,
        )
    }

    /// Intern a whole owned trace.  Deterministic: the resulting store is
    /// identical (spans, instruction ids, metas) to the one the streaming
    /// generator builds for the same trace content.
    pub fn from_requests(reqs: &[Request]) -> TraceStore {
        let bytes: usize = reqs.iter().map(|r| r.user_input.len()).sum();
        let mut store = TraceStore::with_capacity(reqs.len(), bytes);
        for r in reqs {
            store.push_request(r);
        }
        store
    }

    /// Generate a trace directly into a fresh store (streaming; no owned
    /// `Vec<Request>` is ever built).  Content-identical to
    /// [`generate_trace`](crate::workload::generate_trace) for the same
    /// spec.
    pub fn generate(spec: &TraceSpec) -> TraceStore {
        // The task input lengths are lognormal(μ≈4.8, σ≈0.7) clipped to
        // ≤600 tokens → mean ≈150 bytes/request; 160 headroom avoids a
        // mid-generation arena double (whose transient old+new
        // double-residency would land in the scale bench's peak gauge).
        // A spec-level input cap bounds the per-request bytes tighter
        // (text bytes ≈ tokens − 1), so capped specs don't over-reserve.
        let per_request = if spec.l_cap > 0 {
            (spec.l_cap as usize).min(160)
        } else {
            160
        };
        let mut store =
            TraceStore::with_capacity(spec.n_requests, spec.n_requests * per_request);
        let mut gen = StreamingTraceGen::new(spec);
        while gen.next_into(&mut store).is_some() {}
        store
    }

    pub fn len(&self) -> usize {
        match &self.metas {
            MetaTable::Owned(v) => v.len(),
            MetaTable::File { n, .. } => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The compact record of request `i` (trace order).  File-backed
    /// stores decode the 48-byte wire record in place and hash the
    /// span's text on the way out (the `uih` a materialised meta would
    /// carry) — O(one record + one span), independent of trace size.
    /// Panics on an out-of-range index ([`Self::get_meta`] is the
    /// checked form) or, for never-validated corrupt files, on a span
    /// outside the arena.
    #[inline]
    pub fn meta(&self, i: usize) -> RequestMeta {
        match &self.metas {
            MetaTable::Owned(v) => v[i],
            MetaTable::File { bytes, n, aligned, .. } => {
                assert!(i < *n, "meta index {i} out of range ({n} requests)");
                self.decode_meta(bytes, i, *aligned)
            }
        }
    }

    /// [`Self::meta`] without the panicking contract: `None` past the
    /// end of the trace (the CLI boundary resolves `--requests` over a
    /// shorter trace through this).
    #[inline]
    pub fn get_meta(&self, i: usize) -> Option<RequestMeta> {
        (i < self.len()).then(|| self.meta(i))
    }

    /// Arrival time of request `i` without materialising the record —
    /// event-queue seeding reads one field per meta, so replay setup of
    /// a 10⁷-request file does not hash 10⁷ user inputs up front.
    #[inline]
    pub fn arrival(&self, i: usize) -> f64 {
        match &self.metas {
            MetaTable::Owned(v) => v[i].arrival,
            MetaTable::File { bytes, n, aligned, .. } => {
                assert!(i < *n, "meta index {i} out of range ({n} requests)");
                f64::from_bits(wire_meta(bytes, i, *aligned).arrival_bits)
            }
        }
    }

    /// Decode wire record `i` into a [`RequestMeta`] stamped with this
    /// store's provenance, hashing the span bytes for `uih` (bitwise
    /// the hash an eager decode would have computed).
    fn decode_meta(&self, bytes: &FileBytes, i: usize, aligned: bool) -> RequestMeta {
        let w = wire_meta(bytes, i, aligned);
        let task = *TaskId::ALL
            .get(w.task as usize)
            .expect("corrupt trace: meta task id out of range (validate_all rejects this)");
        let end = w
            .span_start
            .checked_add(u64::from(w.span_len))
            .expect("corrupt trace: meta span overflows");
        let arena = self.arena.raw();
        assert!(
            end <= arena.len() as u64,
            "corrupt trace: meta span out of arena bounds (validate_all rejects this)"
        );
        let span_bytes = &arena[w.span_start as usize..end as usize];
        RequestMeta {
            id: w.id,
            task,
            store: self.store_id,
            instr: w.instr,
            user_input_len: w.uil,
            request_len: w.request_len,
            gen_len: w.gen_len,
            arrival: f64::from_bits(w.arrival_bits),
            span: Span {
                start: w.span_start,
                len: w.span_len,
            },
            uih: hash_user_input_bytes(span_bytes),
        }
    }

    /// All compact records, in trace order.  For file-backed stores
    /// this **materialises** (once, memoised) — it is the
    /// slice-compatibility path for tests, goldens and small
    /// comparison sims; scale paths iterate [`Self::meta`] /
    /// [`Self::iter_metas`] instead and never pay it.
    #[inline]
    pub fn metas(&self) -> &[RequestMeta] {
        match &self.metas {
            MetaTable::Owned(v) => v,
            MetaTable::File { cache, .. } => {
                cache.get_or_init(|| (0..self.len()).map(|i| self.meta(i)).collect())
            }
        }
    }

    /// The records one at a time, in trace order, without materialising
    /// a table (file-backed stores decode each in place).
    pub fn iter_metas(&self) -> impl Iterator<Item = RequestMeta> + '_ {
        (0..self.len()).map(move |i| self.meta(i))
    }

    /// A store exposing only the first `min(n, len)` requests — how the
    /// CLI clamps `--requests` over a longer opened trace.  O(1) for
    /// file-backed stores (the mapping, arena and section offsets are
    /// shared; only the visible count shrinks); owned stores copy the
    /// truncated record table.  Shares this store's provenance stamp,
    /// so metas resolve against either.
    pub fn prefix(&self, n: usize) -> TraceStore {
        let n = n.min(self.len());
        let metas = match &self.metas {
            MetaTable::Owned(v) => MetaTable::Owned(v[..n].to_vec()),
            MetaTable::File {
                bytes,
                instr_off,
                aligned,
                ..
            } => MetaTable::File {
                bytes: Arc::clone(bytes),
                n,
                instr_off: *instr_off,
                aligned: *aligned,
                cache: Arc::new(OnceLock::new()),
            },
        };
        TraceStore {
            store_id: self.store_id,
            arena: self.arena.clone(),
            instructions: self.instructions.clone(),
            metas,
        }
    }

    /// One-shot full sweep over a file-backed store: UTF-8 of the whole
    /// arena, then every record's task id, instruction index, span
    /// bounds and span char-boundaries — exactly the checks the
    /// pre-lazy decode ran at open, with the same error texts.  Tools
    /// and tests run it over untrusted files; a clean pass memoises the
    /// arena's validity so later span resolution skips re-checking.
    /// Owned stores hold the invariants by construction.
    pub fn validate_all(&self) -> anyhow::Result<()> {
        let (bytes, n, aligned) = match &self.metas {
            MetaTable::Owned(_) => return Ok(()),
            MetaTable::File {
                bytes, n, aligned, ..
            } => (bytes, *n, *aligned),
        };
        let arena_str = std::str::from_utf8(self.arena.raw())
            .map_err(|e| anyhow::anyhow!("text arena is not UTF-8: {e}"))?;
        let arena_len = arena_str.len();
        for i in 0..n {
            let w = wire_meta(bytes, i, aligned);
            let task_idx = w.task as usize;
            anyhow::ensure!(
                task_idx < TaskId::ALL.len(),
                "meta {i} has bad task id {task_idx}"
            );
            let instr = w.instr;
            anyhow::ensure!(
                (instr as usize) < self.instructions.len(),
                "meta {i} instruction index {instr} out of range ({} entries)",
                self.instructions.len()
            );
            let start = w.span_start;
            let end = start
                .checked_add(u64::from(w.span_len))
                .ok_or_else(|| anyhow::anyhow!("meta {i} span overflows"))?;
            anyhow::ensure!(
                end <= arena_len as u64,
                "meta {i} span [{start}, {end}) points past the {arena_len}-byte arena"
            );
            anyhow::ensure!(
                arena_str.is_char_boundary(start as usize)
                    && arena_str.is_char_boundary(end as usize),
                "meta {i} span [{start}, {end}) splits a UTF-8 sequence"
            );
        }
        if let Arena::File { utf8_ok, .. } = &self.arena {
            utf8_ok.store(true, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Borrow the user-input text of `m` from the arena.
    #[inline]
    pub fn user_input(&self, m: &RequestMeta) -> &str {
        self.check_provenance(m);
        let start = m.span.start as usize;
        &self.arena.as_str()[start..start + m.span.len as usize]
    }

    /// Borrow the instruction text of `m` from the dedup table.
    #[inline]
    pub fn instruction(&self, m: &RequestMeta) -> &str {
        self.check_provenance(m);
        &self.instructions[m.instr as usize]
    }

    /// Zero-copy full view of `m` (the predictor feature input).
    #[inline]
    pub fn view_of(&self, m: &RequestMeta) -> RequestView<'_> {
        RequestView {
            id: m.id,
            task: m.task,
            instruction: self.instruction(m),
            user_input: self.user_input(m),
            user_input_len: m.user_input_len,
            request_len: m.request_len,
            gen_len: m.gen_len,
            arrival: m.arrival,
            uih: m.uih,
        }
    }

    /// Zero-copy view of request `i` (trace order).
    #[inline]
    pub fn view(&self, i: usize) -> RequestView<'_> {
        self.view_of(&self.meta(i))
    }

    /// Materialise `m` as an owned [`Request`] (clones both texts) — the
    /// golden/JSON interchange path, never the serving path.
    pub fn request_of(&self, m: &RequestMeta) -> Request {
        Request {
            id: m.id,
            task: m.task,
            instruction: self.instruction(m).to_string(),
            user_input: self.user_input(m).to_string(),
            user_input_len: m.user_input_len,
            request_len: m.request_len,
            gen_len: m.gen_len,
            arrival: m.arrival,
        }
    }

    /// Materialise the whole trace as owned requests (goldens only).
    pub fn to_requests(&self) -> Vec<Request> {
        self.iter_metas().map(|m| self.request_of(&m)).collect()
    }

    /// Bytes of interned user-input text (the scale bench's arena gauge).
    pub fn arena_bytes(&self) -> usize {
        self.arena.len()
    }

    /// The whole text arena as one `&str` (differential tests compare
    /// backings byte for byte; never needed on the serving path, which
    /// resolves per-span through [`Self::user_input`]).
    pub fn arena_str(&self) -> &str {
        self.arena.as_str()
    }

    /// The deduplicated instruction table, in interning order.
    pub fn instruction_table(&self) -> &[String] {
        &self.instructions
    }

    /// Whether the arena resolves out of an opened trace file (mapped or
    /// read) rather than owned heap text.
    pub fn is_file_backed(&self) -> bool {
        matches!(self.arena, Arena::File { .. })
    }

    /// Whether the arena resolves out of a live read-only mapping (the
    /// kernel pages text on demand; telemetry / bench labelling only).
    pub fn is_mmap_backed(&self) -> bool {
        match &self.arena {
            Arena::Owned(_) => false,
            Arena::File { bytes, .. } => bytes.is_mapped(),
        }
    }

    /// Bytes of the deduplicated instruction table.
    pub fn instruction_bytes(&self) -> usize {
        self.instructions.iter().map(|s| s.len()).sum()
    }

    /// Serialise in the trace JSON schema (`id`/`task`/`user_input`/`uil`/
    /// `len`/`gen`/`arrival`).  Instruction text is **not** emitted — the
    /// task id reconstructs it on load, so the on-disk form is deduped the
    /// same way the store is.  Byte-identical to what
    /// [`trace_to_json`](crate::workload::trace_to_json) emits for the
    /// equivalent owned trace.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.iter_metas()
                .map(|m| {
                    Json::obj(vec![
                        ("id", Json::num(m.id as f64)),
                        ("task", Json::num(m.task.index() as f64)),
                        ("user_input", Json::str(self.user_input(&m).to_string())),
                        ("uil", Json::num(m.user_input_len as f64)),
                        ("len", Json::num(m.request_len as f64)),
                        ("gen", Json::num(m.gen_len as f64)),
                        ("arrival", Json::num(m.arrival)),
                    ])
                })
                .collect(),
        )
    }

    /// Parse a trace (old or new files — the schema never carried
    /// instruction text) directly into a store: instructions reconstruct
    /// from the task id via [`TaskId::instruction`], user inputs intern
    /// into the arena, and no owned `Request` is materialised.  Record
    /// parsing is shared with the owned deserialiser
    /// (`trace::parse_trace_record`), so the two cannot drift.
    pub fn from_json(j: &Json) -> anyhow::Result<TraceStore> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("trace: expected array"))?;
        // Exact arena size is already known from the parsed items.
        let bytes: usize = arr
            .iter()
            .map(|item| item.get("user_input").as_str().map_or(0, str::len))
            .sum();
        let mut store = TraceStore::with_capacity(arr.len(), bytes);
        for item in arr {
            let rec = crate::workload::trace::parse_trace_record(item)?;
            store.push(
                rec.id,
                rec.task,
                rec.task.instruction(),
                rec.user_input,
                rec.user_input_len,
                rec.request_len,
                rec.gen_len,
                rec.arrival,
            );
        }
        Ok(store)
    }

    /// Serialise in the binary trace format (see the module docs for the
    /// exact layout).  Works on any backing — a file-opened store
    /// re-serialises byte-exactly from its mapped sections (no meta
    /// materialisation); an owned store encodes its records, after the
    /// wire-limit check ([`check_wire_limits`]): a store whose
    /// instruction or meta count would truncate a wire field is an
    /// error here, never a silently corrupt file.
    pub fn to_binary(&self) -> anyhow::Result<Vec<u8>> {
        let metas = match &self.metas {
            MetaTable::Owned(v) => v,
            MetaTable::File {
                bytes, n, instr_off, ..
            } => {
                let b: &[u8] = bytes;
                let (arena_off, arena_len) = match &self.arena {
                    Arena::File { offset, len, .. } => (*offset, *len),
                    // A file meta table always pairs with a file arena.
                    Arena::Owned(_) => unreachable!("file metas with owned arena"),
                };
                let instr_bytes = arena_off - instr_off;
                let mut out = Vec::with_capacity(
                    TRACE_HEADER_BYTES + n * TRACE_META_BYTES + instr_bytes + arena_len,
                );
                out.extend_from_slice(&TRACE_MAGIC);
                out.extend_from_slice(&TRACE_VERSION.to_le_bytes());
                out.extend_from_slice(&0u32.to_le_bytes()); // reserved
                out.extend_from_slice(&(*n as u64).to_le_bytes());
                out.extend_from_slice(&(self.instructions.len() as u64).to_le_bytes());
                out.extend_from_slice(&(instr_bytes as u64).to_le_bytes());
                out.extend_from_slice(&(arena_len as u64).to_le_bytes());
                out.extend_from_slice(&b[TRACE_HEADER_BYTES..TRACE_HEADER_BYTES + n * TRACE_META_BYTES]);
                out.extend_from_slice(&b[*instr_off..arena_off + arena_len]);
                return Ok(out);
            }
        };
        check_wire_limits(metas.len() as u64, self.instructions.iter().map(|s| s.len()))?;
        let instr_bytes: usize = self.instructions.iter().map(|s| 4 + s.len()).sum();
        let arena = self.arena.as_str().as_bytes();
        let mut out = Vec::with_capacity(
            TRACE_HEADER_BYTES + metas.len() * TRACE_META_BYTES + instr_bytes + arena.len(),
        );
        out.extend_from_slice(&TRACE_MAGIC);
        out.extend_from_slice(&TRACE_VERSION.to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes()); // reserved
        out.extend_from_slice(&(metas.len() as u64).to_le_bytes());
        out.extend_from_slice(&(self.instructions.len() as u64).to_le_bytes());
        out.extend_from_slice(&(instr_bytes as u64).to_le_bytes());
        out.extend_from_slice(&(arena.len() as u64).to_le_bytes());
        for m in metas {
            out.extend_from_slice(&m.id.to_le_bytes());
            out.extend_from_slice(&m.arrival.to_bits().to_le_bytes());
            out.extend_from_slice(&m.span.start.to_le_bytes());
            out.extend_from_slice(&m.span.len.to_le_bytes());
            out.extend_from_slice(&(m.task.index() as u32).to_le_bytes());
            out.extend_from_slice(&m.instr.to_le_bytes());
            out.extend_from_slice(&m.user_input_len.to_le_bytes());
            out.extend_from_slice(&m.request_len.to_le_bytes());
            out.extend_from_slice(&m.gen_len.to_le_bytes());
        }
        for s in &self.instructions {
            out.extend_from_slice(&(s.len() as u32).to_le_bytes());
            out.extend_from_slice(s.as_bytes());
        }
        out.extend_from_slice(arena);
        Ok(out)
    }

    /// Write the binary trace format to `path`
    /// ([`Self::open_mmap`] / [`Self::open_read`] reopen it).
    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        let path = path.as_ref();
        let bytes = self.to_binary()?;
        std::fs::write(path, bytes)
            .map_err(|e| anyhow::anyhow!("trace write {}: {e}", path.display()))
    }

    /// Open a binary trace file through a read-only mapping: O(metas)
    /// load, the kernel pages the text arena on demand, and multiple
    /// processes share the page cache.  Falls back to an in-memory read
    /// on platforms/filesystems without mmap — same decode, same
    /// validation, same errors either way.
    ///
    /// The file must not be modified while the store is alive (see
    /// [`crate::util::mmap`]'s caveat): validation happens once at
    /// open, so an external in-place writer would invalidate it.  Use
    /// [`Self::open_read`] for files a concurrent writer may touch.
    pub fn open_mmap<P: AsRef<Path>>(path: P) -> anyhow::Result<TraceStore> {
        let path = path.as_ref();
        let bytes = map_file(path)
            .map_err(|e| anyhow::anyhow!("trace open {}: {e}", path.display()))?;
        TraceStore::decode(bytes)
            .map_err(|e| anyhow::anyhow!("trace open {}: {e}", path.display()))
    }

    /// Open a binary trace file by reading it fully into memory — the
    /// explicit fallback route, sharing [`Self::open_mmap`]'s decode.
    pub fn open_read<P: AsRef<Path>>(path: P) -> anyhow::Result<TraceStore> {
        let path = path.as_ref();
        let bytes = read_file(path)
            .map_err(|e| anyhow::anyhow!("trace open {}: {e}", path.display()))?;
        TraceStore::decode(bytes)
            .map_err(|e| anyhow::anyhow!("trace open {}: {e}", path.display()))
    }

    /// Decode the binary trace format from in-memory bytes (the corrupt-
    /// input tests drive the exact file decode route without a file).
    pub fn from_binary_bytes(bytes: Vec<u8>) -> anyhow::Result<TraceStore> {
        TraceStore::decode(FileBytes::Owned(bytes))
    }

    /// The single decode route behind [`Self::open_mmap`],
    /// [`Self::open_read`] and [`Self::from_binary_bytes`] — **O(1) in
    /// the meta count**.  The header, section bounds and the (tiny)
    /// instruction table are validated before the store is constructed;
    /// per-meta invariants (task/instruction indices, span bounds and
    /// UTF-8) are checked lazily at access, or all at once by
    /// [`Self::validate_all`].  A structurally corrupt container yields
    /// an error here, never a panic.
    fn decode(bytes: FileBytes) -> anyhow::Result<TraceStore> {
        let b: &[u8] = &bytes;
        anyhow::ensure!(
            b.len() >= TRACE_HEADER_BYTES,
            "truncated header ({} of {TRACE_HEADER_BYTES} bytes)",
            b.len()
        );
        anyhow::ensure!(b[..8] == TRACE_MAGIC, "bad magic (not a binary trace file)");
        let version = rd_u32(b, 8);
        anyhow::ensure!(
            version == TRACE_VERSION,
            "unsupported format version {version} (this build reads {TRACE_VERSION})"
        );
        let reserved = rd_u32(b, 12);
        anyhow::ensure!(reserved == 0, "reserved header field is {reserved}, not 0");
        let n_metas_u64 = rd_u64(b, 16);
        let n_instr_u64 = rd_u64(b, 24);
        let instr_bytes_u64 = rd_u64(b, 32);
        let arena_bytes_u64 = rd_u64(b, 40);

        // Section sizes must reproduce the file length exactly, under
        // checked arithmetic so corrupt counts cannot wrap.
        let described = n_metas_u64
            .checked_mul(TRACE_META_BYTES as u64)
            .and_then(|v| v.checked_add(TRACE_HEADER_BYTES as u64))
            .and_then(|v| v.checked_add(instr_bytes_u64))
            .and_then(|v| v.checked_add(arena_bytes_u64))
            .ok_or_else(|| anyhow::anyhow!("corrupt section counts (overflow)"))?;
        anyhow::ensure!(
            described == b.len() as u64,
            "file is {} bytes but the header describes {described}",
            b.len()
        );
        // All counts now fit in usize: described ≤ b.len() ≤ usize::MAX.
        let n_metas = n_metas_u64 as usize;
        let n_instr = n_instr_u64 as usize;
        let instr_bytes = instr_bytes_u64 as usize;
        let arena_len = arena_bytes_u64 as usize;
        let min_table = n_instr_u64
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("corrupt instruction count {n_instr}"))?;
        anyhow::ensure!(
            min_table <= instr_bytes_u64,
            "instruction count {n_instr} cannot fit its {instr_bytes}-byte table"
        );

        let meta_off = TRACE_HEADER_BYTES;
        let instr_off = meta_off + n_metas * TRACE_META_BYTES;
        let arena_off = instr_off + instr_bytes;

        // Instruction table: length-prefixed UTF-8, consumed exactly.
        let it = &b[instr_off..arena_off];
        let mut instructions = Vec::with_capacity(n_instr);
        let mut p = 0usize;
        for i in 0..n_instr {
            anyhow::ensure!(p + 4 <= it.len(), "instruction table truncated at entry {i}");
            let len = rd_u32(it, p) as usize;
            p += 4;
            anyhow::ensure!(
                len <= it.len() - p,
                "instruction {i} ({len} bytes) overruns its table"
            );
            let s = std::str::from_utf8(&it[p..p + len])
                .map_err(|e| anyhow::anyhow!("instruction {i} is not UTF-8: {e}"))?;
            instructions.push(s.to_string());
            p += len;
        }
        anyhow::ensure!(
            p == it.len(),
            "instruction table has {} trailing bytes",
            it.len() - p
        );

        // That is the whole open: the meta table stays in place behind
        // the alignment-checked view and the arena is untouched.  The
        // pointer survives moving `bytes` into the Arc below (a Vec's
        // heap block and an mmap'd region are both address-stable).
        let aligned = (b.as_ptr() as usize + meta_off) % std::mem::align_of::<RawMeta>() == 0;
        let store_id = StoreId::mint();
        let bytes = Arc::new(bytes);
        Ok(TraceStore {
            store_id,
            arena: Arena::File {
                bytes: Arc::clone(&bytes),
                offset: arena_off,
                len: arena_len,
                utf8_ok: Arc::new(AtomicBool::new(false)),
            },
            instructions,
            metas: MetaTable::File {
                bytes,
                n: n_metas,
                instr_off,
                aligned,
                cache: Arc::new(OnceLock::new()),
            },
        })
    }
}

/// Wire-format field limits, checked **before** encoding so an
/// over-wide store is an error instead of a silently truncated file:
/// each instruction is length-prefixed with a `u32`, and a single
/// binary trace caps its meta count at `u32::MAX` records (shard
/// anything bigger).  Split out from [`TraceStore::to_binary`] so the
/// oversize paths are unit-testable without allocating 4-GiB strings.
pub(crate) fn check_wire_limits<I>(n_metas: u64, instruction_lens: I) -> anyhow::Result<()>
where
    I: IntoIterator<Item = usize>,
{
    anyhow::ensure!(
        n_metas <= u64::from(u32::MAX),
        "trace has {n_metas} requests; a single binary trace file caps at {} (shard it)",
        u32::MAX
    );
    for (i, len) in instruction_lens.into_iter().enumerate() {
        anyhow::ensure!(
            len as u64 <= u64::from(u32::MAX),
            "instruction {i} is {len} bytes; the wire format length-prefixes instructions with a u32"
        );
    }
    Ok(())
}

/// Anything a simulator or server can replay a trace out of: a single
/// [`TraceStore`], or a [`ShardedTrace`](crate::workload::ShardedTrace)
/// presenting its shards as one global index space.  The serving loops
/// (`sim::magnus`, `cluster::sim`, continuous learning) are generic
/// over this, so a multi-shard trace replays without ever being
/// concatenated into one store.
pub trait TraceSource {
    /// Number of requests.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Arrival time of request `i` (event seeding reads one field per
    /// request — implementations keep this cheaper than [`Self::meta`]).
    fn arrival(&self, i: usize) -> f64;
    /// The compact record of request `i`.
    fn meta(&self, i: usize) -> RequestMeta;
    /// Zero-copy view of request `i`.
    fn view(&self, i: usize) -> RequestView<'_>;
    /// Zero-copy view of a meta minted by this source (sharded sources
    /// resolve it against the shard that minted it).
    fn view_of(&self, m: &RequestMeta) -> RequestView<'_>;
    /// The serving instance owning request `i` under one-shard-per-
    /// instance mapping; `None` for unsharded sources.
    fn home_of(&self, i: usize) -> Option<usize> {
        let _ = i;
        None
    }
}

impl TraceSource for TraceStore {
    #[inline]
    fn len(&self) -> usize {
        TraceStore::len(self)
    }
    #[inline]
    fn arrival(&self, i: usize) -> f64 {
        TraceStore::arrival(self, i)
    }
    #[inline]
    fn meta(&self, i: usize) -> RequestMeta {
        TraceStore::meta(self, i)
    }
    #[inline]
    fn view(&self, i: usize) -> RequestView<'_> {
        TraceStore::view(self, i)
    }
    #[inline]
    fn view_of(&self, m: &RequestMeta) -> RequestView<'_> {
        TraceStore::view_of(self, m)
    }
}

/// Read a little-endian `u32` at `off` (bounds pre-checked by callers).
#[inline]
fn rd_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
}

/// Read a little-endian `u64` at `off` (bounds pre-checked by callers).
#[inline]
fn rd_u64(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

/// Streaming trace generator: Poisson arrivals over the weighted task mix
/// (exactly [`generate_trace`](crate::workload::generate_trace)'s model
/// and RNG sequence), yielding one [`RequestMeta`] at a time and writing
/// each text straight into the target store's arena.
pub struct StreamingTraceGen {
    spec: TraceSpec,
    rng: Rng,
    tok: Tokenizer,
    weights: Vec<f64>,
    t: f64,
    next: usize,
}

impl StreamingTraceGen {
    pub fn new(spec: &TraceSpec) -> StreamingTraceGen {
        let weights = if spec.task_weights.len() == TaskId::ALL.len() {
            spec.task_weights.clone()
        } else {
            vec![1.0; TaskId::ALL.len()]
        };
        StreamingTraceGen {
            spec: spec.clone(),
            rng: Rng::new(spec.seed),
            tok: Tokenizer::new(),
            weights,
            t: 0.0,
            next: 0,
        }
    }

    /// Requests not yet generated.
    pub fn remaining(&self) -> usize {
        self.spec.n_requests - self.next
    }

    /// Generate the next request into `store`; `None` once the spec's
    /// request count is exhausted.
    pub fn next_into(&mut self, store: &mut TraceStore) -> Option<RequestMeta> {
        if self.next >= self.spec.n_requests {
            return None;
        }
        self.t += self.rng.exponential(self.spec.rate);
        let task = TaskId::ALL[self.rng.weighted_index(&self.weights)];
        let shape = sample_shape(
            task,
            self.spec.llm,
            self.spec.g_max,
            self.spec.l_cap,
            &mut self.rng,
        );
        let instruction = task.instruction();
        // The probe is over a ≤ 8-entry table whose non-matching entries
        // fail on their first bytes — noise next to the text synthesis —
        // and stays correct however many stores one generator targets.
        let instr = store.intern_instruction(instruction);
        // Text is synthesised at its final arena address — the only copy.
        let start = store.arena.len() as u64;
        synth_input_into(
            task,
            shape.topic,
            shape.user_input_len,
            &mut self.rng,
            store.arena.owned_mut(),
        );
        let text_len = store.arena.len() - start as usize;
        let request_len = (self.tok.token_len(instruction) + text_len) as u32;
        let meta = store.record_meta(
            self.next as u64,
            task,
            instr,
            shape.user_input_len,
            request_len,
            shape.gen_len,
            self.t,
            start,
        );
        self.next += 1;
        Some(meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::workload::generate_trace;

    #[test]
    fn streaming_generation_matches_owned_generation() {
        let spec = TraceSpec {
            rate: 3.0,
            n_requests: 400,
            seed: 11,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let store = TraceStore::generate(&spec);
        assert_eq!(store.len(), owned.len());
        for (i, r) in owned.iter().enumerate() {
            let m = store.meta(i);
            assert_eq!(m.id, r.id);
            assert_eq!(m.task, r.task);
            assert_eq!(m.user_input_len, r.user_input_len);
            assert_eq!(m.request_len, r.request_len);
            assert_eq!(m.gen_len, r.gen_len);
            assert_eq!(m.arrival.to_bits(), r.arrival.to_bits());
            assert_eq!(store.user_input(&m), r.user_input);
            assert_eq!(store.instruction(&m), r.instruction);
        }
        // Arena holds exactly the concatenated inputs, nothing more.
        let bytes: usize = owned.iter().map(|r| r.user_input.len()).sum();
        assert_eq!(store.arena_bytes(), bytes);
        // Instructions deduplicated: at most one entry per task.
        assert!(store.instructions.len() <= TaskId::ALL.len());
    }

    #[test]
    fn interning_owned_trace_equals_streaming_store() {
        let spec = TraceSpec {
            rate: 5.0,
            n_requests: 150,
            seed: 23,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let a = TraceStore::generate(&spec);
        let b = TraceStore::from_requests(&owned);
        assert_eq!(a.metas(), b.metas());
        assert_eq!(a.arena_str(), b.arena_str());
        assert_eq!(a.instructions, b.instructions);
        // Content-equal, provenance-distinct: each minted its own stamp.
        assert_ne!(a.id(), b.id());
    }

    #[test]
    fn arena_interning_roundtrips_every_sampled_text() {
        // Satellite property test: for random specs, every interned text
        // (and the materialised owned request) round-trips exactly.
        prop_check(12, |rng| {
            let spec = TraceSpec {
                rate: rng.range_f64(0.5, 20.0),
                n_requests: rng.range_usize(1, 120),
                l_cap: if rng.range_u64(0, 2) == 0 {
                    0
                } else {
                    rng.range_u64(8, 200) as u32
                },
                seed: rng.next_u64(),
                ..Default::default()
            };
            let owned = generate_trace(&spec);
            let store = TraceStore::generate(&spec);
            for (i, r) in owned.iter().enumerate() {
                let view = store.view(i);
                assert_eq!(view.user_input, r.user_input);
                assert_eq!(view.instruction, r.instruction);
                let back = store.request_of(&store.meta(i));
                assert_eq!(back.id, r.id);
                assert_eq!(back.task, r.task);
                assert_eq!(back.instruction, r.instruction);
                assert_eq!(back.user_input, r.user_input);
                assert_eq!(back.user_input_len, r.user_input_len);
                assert_eq!(back.request_len, r.request_len);
                assert_eq!(back.gen_len, r.gen_len);
                assert_eq!(back.arrival.to_bits(), r.arrival.to_bits());
            }
        });
    }

    #[test]
    fn json_roundtrip_via_store_matches_owned_schema() {
        let spec = TraceSpec {
            n_requests: 40,
            ..Default::default()
        };
        let store = TraceStore::generate(&spec);
        let owned = generate_trace(&spec);
        // The store emits the exact bytes the owned serialiser does (and
        // neither ever emits instruction text — deduped via the task id).
        let a = store.to_json().to_string();
        let b = crate::workload::trace_to_json(&owned).to_string();
        assert_eq!(a, b);
        assert!(!a.contains("Translate the following"));
        // And parses straight back into an identical store.
        let back = TraceStore::from_json(&Json::parse(&a).unwrap()).unwrap();
        assert_eq!(back.metas(), store.metas());
        assert_eq!(back.arena_bytes(), store.arena_bytes());
    }

    #[test]
    fn streaming_gen_is_resumable_mid_trace() {
        let spec = TraceSpec {
            n_requests: 60,
            seed: 5,
            ..Default::default()
        };
        let whole = TraceStore::generate(&spec);
        let mut store = TraceStore::new();
        let mut gen = StreamingTraceGen::new(&spec);
        let mut n = 0;
        while let Some(m) = gen.next_into(&mut store) {
            assert_eq!(m, whole.meta(n));
            n += 1;
            assert_eq!(gen.remaining(), spec.n_requests - n);
        }
        assert_eq!(n, 60);
        assert!(gen.next_into(&mut store).is_none());
    }

    #[test]
    fn detached_meta_carries_numbers_only() {
        let spec = TraceSpec {
            n_requests: 3,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let m = RequestMeta::detached(&owned[1]);
        assert_eq!(m.id, owned[1].id);
        assert_eq!(m.request_len, owned[1].request_len);
        assert_eq!(m.gen_len, owned[1].gen_len);
        // Both text addresses are sentinels: accidental resolution
        // panics (out of bounds) rather than aliasing a live store's
        // first instruction or yielding "".
        assert_eq!(m.instr, u32::MAX);
        assert_eq!(m.span, Span::DETACHED);
    }

    #[test]
    #[should_panic]
    fn resolving_detached_instruction_against_store_panics() {
        let spec = TraceSpec {
            n_requests: 2,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let store = TraceStore::generate(&spec);
        let detached = RequestMeta::detached(&owned[0]);
        let _ = store.instruction(&detached);
    }

    #[test]
    #[should_panic]
    fn resolving_detached_user_input_against_store_panics() {
        let spec = TraceSpec {
            n_requests: 2,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let store = TraceStore::generate(&spec);
        let detached = RequestMeta::detached(&owned[0]);
        let _ = store.user_input(&detached);
    }

    #[test]
    fn binary_roundtrip_preserves_everything() {
        let spec = TraceSpec {
            n_requests: 80,
            seed: 9,
            ..Default::default()
        };
        let store = TraceStore::generate(&spec);
        let bytes = store.to_binary().unwrap();
        let back = TraceStore::from_binary_bytes(bytes.clone()).unwrap();
        assert_eq!(back.metas(), store.metas());
        assert_eq!(back.arena_str(), store.arena_str());
        assert_eq!(back.instruction_table(), store.instruction_table());
        assert!(back.is_file_backed());
        assert!(!back.is_mmap_backed()); // in-memory bytes, fallback route
        // Loaded metas carry the fresh store's provenance stamp, so they
        // resolve here (and, in debug builds, nowhere else).
        assert!(back.metas().iter().all(|m| m.store == back.id()));
        for i in 0..store.len() {
            let (a, b) = (store.view(i), back.view(i));
            assert_eq!(a.user_input, b.user_input);
            assert_eq!(a.instruction, b.instruction);
        }
        // A file-opened store re-serialises to the bytes it came from.
        assert_eq!(back.to_binary().unwrap(), bytes);
    }

    #[test]
    fn binary_roundtrip_of_empty_store() {
        let store = TraceStore::new();
        let back = TraceStore::from_binary_bytes(store.to_binary().unwrap()).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.arena_bytes(), 0);
    }

    #[test]
    fn lazy_open_resolves_records_in_place_and_validates() {
        let spec = TraceSpec {
            n_requests: 120,
            seed: 31,
            ..Default::default()
        };
        let store = TraceStore::generate(&spec);
        let back = TraceStore::from_binary_bytes(store.to_binary().unwrap()).unwrap();
        // Per-record access (no `metas()` call anywhere): every field,
        // the lazily computed uih, and both texts match the source.
        assert_eq!(back.len(), store.len());
        for i in 0..store.len() {
            assert_eq!(back.meta(i), store.meta(i));
            assert_eq!(back.arrival(i).to_bits(), store.meta(i).arrival.to_bits());
            assert_eq!(back.view(i).user_input, store.view(i).user_input);
            assert_eq!(back.view(i).instruction, store.view(i).instruction);
        }
        // The full sweep passes on a well-formed file, and the
        // whole-arena view agrees with the owned one afterwards.
        back.validate_all().unwrap();
        assert_eq!(back.arena_str(), store.arena_str());
    }

    #[test]
    fn validate_all_rejects_corrupt_records_that_open_accepts() {
        let spec = TraceSpec {
            n_requests: 10,
            seed: 7,
            ..Default::default()
        };
        let store = TraceStore::generate(&spec);
        let good = store.to_binary().unwrap();

        // Span of meta 3 pushed past the arena: the container is still
        // structurally valid, so the O(1) open succeeds — the sweep
        // catches it.
        let mut bad = good.clone();
        let off = TRACE_HEADER_BYTES + 3 * TRACE_META_BYTES + 16;
        bad[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let opened = TraceStore::from_binary_bytes(bad).unwrap();
        let err = opened.validate_all().unwrap_err().to_string();
        assert!(err.contains("meta 3"), "unexpected error: {err}");

        // Bad task id, same shape.
        let mut bad = good.clone();
        let off = TRACE_HEADER_BYTES + 5 * TRACE_META_BYTES + 28;
        bad[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let opened = TraceStore::from_binary_bytes(bad).unwrap();
        assert!(opened.validate_all().is_err());

        // And the untouched file still passes.
        TraceStore::from_binary_bytes(good)
            .unwrap()
            .validate_all()
            .unwrap();
    }

    #[test]
    fn wire_limits_reject_oversize_fields() {
        // Mocked-oversize paths: no multi-GiB allocations needed.
        assert!(check_wire_limits(10, [4usize, 90].into_iter()).is_ok());
        let err = check_wire_limits(u64::from(u32::MAX) + 1, std::iter::empty())
            .unwrap_err()
            .to_string();
        assert!(err.contains("shard"), "unexpected error: {err}");
        let err = check_wire_limits(1, [8usize, u32::MAX as usize + 1].into_iter())
            .unwrap_err()
            .to_string();
        assert!(err.contains("instruction 1"), "unexpected error: {err}");
    }

    #[test]
    fn fallback_field_decode_matches_owned_records() {
        // Drive `wire_meta` with the byte-decode route explicitly
        // (aligned = false) and check every record round-trips exactly
        // — this is what misaligned buffers and big-endian targets run.
        let spec = TraceSpec {
            n_requests: 60,
            seed: 13,
            ..Default::default()
        };
        let store = TraceStore::generate(&spec);
        let bytes = store.to_binary().unwrap();
        for i in 0..store.len() {
            let w = wire_meta(&bytes, i, false);
            let m = store.meta(i);
            assert_eq!(w.id, m.id);
            assert_eq!(w.arrival_bits, m.arrival.to_bits());
            assert_eq!(w.span_start, m.span.start);
            assert_eq!(w.span_len, m.span.len);
            assert_eq!(w.task, m.task.index() as u32);
            assert_eq!(w.instr, m.instr);
            assert_eq!(w.uil, m.user_input_len);
            assert_eq!(w.request_len, m.request_len);
            assert_eq!(w.gen_len, m.gen_len);
        }
    }

    #[test]
    fn get_meta_is_checked_and_prefix_clamps() {
        let spec = TraceSpec {
            n_requests: 30,
            seed: 3,
            ..Default::default()
        };
        let store = TraceStore::generate(&spec);
        assert!(store.get_meta(29).is_some());
        assert!(store.get_meta(30).is_none());

        // Owned prefix: shorter view, shared provenance, resolvable.
        let head = store.prefix(7);
        assert_eq!(head.len(), 7);
        assert_eq!(head.id(), store.id());
        assert_eq!(head.view(6).user_input, store.view(6).user_input);
        assert_eq!(store.prefix(1_000).len(), 30);

        // File-backed prefix: O(1) clamp over the shared mapping, and
        // it re-serialises to a valid shorter trace.
        let back = TraceStore::from_binary_bytes(store.to_binary().unwrap()).unwrap();
        let fhead = back.prefix(7);
        assert_eq!(fhead.len(), 7);
        assert!(fhead.get_meta(7).is_none());
        assert_eq!(fhead.view(3).user_input, store.view(3).user_input);
        let reopened = TraceStore::from_binary_bytes(fhead.to_binary().unwrap()).unwrap();
        assert_eq!(reopened.len(), 7);
        reopened.validate_all().unwrap();
        assert_eq!(reopened.view(5).user_input, store.view(5).user_input);
    }

    #[test]
    fn clone_shares_provenance_and_resolves() {
        let store = TraceStore::generate(&TraceSpec {
            n_requests: 4,
            ..Default::default()
        });
        let clone = store.clone();
        assert_eq!(store.id(), clone.id());
        let m = store.meta(2);
        assert_eq!(store.user_input(&m), clone.user_input(&m));
    }
}
