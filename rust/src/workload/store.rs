//! Interned trace storage: the zero-copy backbone of the request path.
//!
//! A [`TraceStore`] owns all request text of a workload exactly once:
//!
//! * user-input texts live back-to-back in one contiguous byte **arena**
//!   (one `String`), addressed by [`Span`]s;
//! * instruction texts — a handful of distinct strings repeated across
//!   every request of a task — live in a deduplicated side table,
//!   addressed by index (the seed cloned `task.instruction()` into every
//!   single request);
//! * each request is a compact, `Copy` [`RequestMeta`] carrying the
//!   numeric fields plus those two addresses.
//!
//! The serving pipeline moves `RequestMeta` (and
//! [`PredictedRequest`](crate::workload::PredictedRequest)) by value —
//! arrival, batching, dispatch and logging perform **zero per-request
//! heap allocations**; text consumers (predictor features, real-compute
//! tokenization) borrow `&str` slices straight from the arena via
//! [`TraceStore::view_of`].
//!
//! [`StreamingTraceGen`] generates workloads **into** the store: each
//! request's text is synthesised at its final arena address
//! (`apps::synth_input_into`), so a million-request trace never exists as
//! a `Vec<Request>` of owned strings.  The stream is RNG-for-RNG and
//! byte-for-byte identical to the owned
//! [`generate_trace`](crate::workload::generate_trace) — property-tested
//! in `tests/store_equivalence.rs`.
//!
//! The owned [`Request`] remains the interchange form: JSON round-trips
//! ([`TraceStore::to_json`] emits the exact schema `trace_to_json` always
//! did — task id, never instruction text) and the golden-equivalence
//! reference (`sim::reference`) materialise through
//! [`TraceStore::request_of`] / [`TraceStore::to_requests`].

use crate::tokenizer::Tokenizer;
use crate::util::{Json, Rng};
use crate::workload::apps::{sample_shape, synth_input_into, TaskId};
use crate::workload::request::{Request, RequestMeta, RequestView, Span};
use crate::workload::trace::TraceSpec;

/// All text of a workload trace, interned once, plus the compact
/// per-request records addressing it.
#[derive(Debug, Clone, Default)]
pub struct TraceStore {
    /// Every request's user-input text, back to back.
    arena: String,
    /// Deduplicated instruction texts (typically one per task).
    instructions: Vec<String>,
    /// Compact per-request records, in trace order.
    metas: Vec<RequestMeta>,
}

impl TraceStore {
    pub fn new() -> TraceStore {
        TraceStore::default()
    }

    /// Store with pre-sized buffers (`arena_bytes` is a hint, not a cap).
    pub fn with_capacity(n_requests: usize, arena_bytes: usize) -> TraceStore {
        TraceStore {
            arena: String::with_capacity(arena_bytes),
            instructions: Vec::new(),
            metas: Vec::with_capacity(n_requests),
        }
    }

    /// Index of `instruction` in the dedup table, interning it if new.
    /// Linear probe: the table holds a handful of distinct entries.
    fn intern_instruction(&mut self, instruction: &str) -> u32 {
        if let Some(i) = self.instructions.iter().position(|s| s == instruction) {
            return i as u32;
        }
        self.instructions.push(instruction.to_string());
        (self.instructions.len() - 1) as u32
    }

    /// Record the meta for a request whose user-input text was just
    /// appended to the arena starting at byte `start` — the single place
    /// the span/meta bookkeeping invariant lives (shared by [`Self::push`]
    /// and the streaming generator, which writes text into the arena
    /// directly).
    #[allow(clippy::too_many_arguments)]
    fn record_meta(
        &mut self,
        id: u64,
        task: TaskId,
        instr: u32,
        user_input_len: u32,
        request_len: u32,
        gen_len: u32,
        arrival: f64,
        start: u64,
    ) -> RequestMeta {
        let meta = RequestMeta {
            id,
            task,
            instr,
            user_input_len,
            request_len,
            gen_len,
            arrival,
            span: Span {
                start,
                len: (self.arena.len() as u64 - start) as u32,
            },
        };
        self.metas.push(meta);
        meta
    }

    /// Intern one request: the instruction is deduplicated, the user input
    /// appended to the arena, and the returned meta (also recorded in the
    /// store) addresses both.
    #[allow(clippy::too_many_arguments)]
    pub fn push(
        &mut self,
        id: u64,
        task: TaskId,
        instruction: &str,
        user_input: &str,
        user_input_len: u32,
        request_len: u32,
        gen_len: u32,
        arrival: f64,
    ) -> RequestMeta {
        let instr = self.intern_instruction(instruction);
        let start = self.arena.len() as u64;
        self.arena.push_str(user_input);
        self.record_meta(
            id,
            task,
            instr,
            user_input_len,
            request_len,
            gen_len,
            arrival,
            start,
        )
    }

    /// Intern an owned request (text copied into the arena once).
    pub fn push_request(&mut self, r: &Request) -> RequestMeta {
        self.push(
            r.id,
            r.task,
            &r.instruction,
            &r.user_input,
            r.user_input_len,
            r.request_len,
            r.gen_len,
            r.arrival,
        )
    }

    /// Intern a whole owned trace.  Deterministic: the resulting store is
    /// identical (spans, instruction ids, metas) to the one the streaming
    /// generator builds for the same trace content.
    pub fn from_requests(reqs: &[Request]) -> TraceStore {
        let bytes: usize = reqs.iter().map(|r| r.user_input.len()).sum();
        let mut store = TraceStore::with_capacity(reqs.len(), bytes);
        for r in reqs {
            store.push_request(r);
        }
        store
    }

    /// Generate a trace directly into a fresh store (streaming; no owned
    /// `Vec<Request>` is ever built).  Content-identical to
    /// [`generate_trace`](crate::workload::generate_trace) for the same
    /// spec.
    pub fn generate(spec: &TraceSpec) -> TraceStore {
        // The task input lengths are lognormal(μ≈4.8, σ≈0.7) clipped to
        // ≤600 tokens → mean ≈150 bytes/request; 160 headroom avoids a
        // mid-generation arena double (whose transient old+new
        // double-residency would land in the scale bench's peak gauge).
        // A spec-level input cap bounds the per-request bytes tighter
        // (text bytes ≈ tokens − 1), so capped specs don't over-reserve.
        let per_request = if spec.l_cap > 0 {
            (spec.l_cap as usize).min(160)
        } else {
            160
        };
        let mut store =
            TraceStore::with_capacity(spec.n_requests, spec.n_requests * per_request);
        let mut gen = StreamingTraceGen::new(spec);
        while gen.next_into(&mut store).is_some() {}
        store
    }

    pub fn len(&self) -> usize {
        self.metas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metas.is_empty()
    }

    /// The compact record of request `i` (trace order).
    #[inline]
    pub fn meta(&self, i: usize) -> RequestMeta {
        self.metas[i]
    }

    /// All compact records, in trace order.
    #[inline]
    pub fn metas(&self) -> &[RequestMeta] {
        &self.metas
    }

    /// Borrow the user-input text of `m` from the arena.
    #[inline]
    pub fn user_input(&self, m: &RequestMeta) -> &str {
        let start = m.span.start as usize;
        &self.arena[start..start + m.span.len as usize]
    }

    /// Borrow the instruction text of `m` from the dedup table.
    #[inline]
    pub fn instruction(&self, m: &RequestMeta) -> &str {
        &self.instructions[m.instr as usize]
    }

    /// Zero-copy full view of `m` (the predictor feature input).
    #[inline]
    pub fn view_of(&self, m: &RequestMeta) -> RequestView<'_> {
        RequestView {
            id: m.id,
            task: m.task,
            instruction: self.instruction(m),
            user_input: self.user_input(m),
            user_input_len: m.user_input_len,
            request_len: m.request_len,
            gen_len: m.gen_len,
            arrival: m.arrival,
        }
    }

    /// Zero-copy view of request `i` (trace order).
    #[inline]
    pub fn view(&self, i: usize) -> RequestView<'_> {
        self.view_of(&self.metas[i])
    }

    /// Materialise `m` as an owned [`Request`] (clones both texts) — the
    /// golden/JSON interchange path, never the serving path.
    pub fn request_of(&self, m: &RequestMeta) -> Request {
        Request {
            id: m.id,
            task: m.task,
            instruction: self.instruction(m).to_string(),
            user_input: self.user_input(m).to_string(),
            user_input_len: m.user_input_len,
            request_len: m.request_len,
            gen_len: m.gen_len,
            arrival: m.arrival,
        }
    }

    /// Materialise the whole trace as owned requests (goldens only).
    pub fn to_requests(&self) -> Vec<Request> {
        self.metas.iter().map(|m| self.request_of(m)).collect()
    }

    /// Bytes of interned user-input text (the scale bench's arena gauge).
    pub fn arena_bytes(&self) -> usize {
        self.arena.len()
    }

    /// Bytes of the deduplicated instruction table.
    pub fn instruction_bytes(&self) -> usize {
        self.instructions.iter().map(|s| s.len()).sum()
    }

    /// Serialise in the trace JSON schema (`id`/`task`/`user_input`/`uil`/
    /// `len`/`gen`/`arrival`).  Instruction text is **not** emitted — the
    /// task id reconstructs it on load, so the on-disk form is deduped the
    /// same way the store is.  Byte-identical to what
    /// [`trace_to_json`](crate::workload::trace_to_json) emits for the
    /// equivalent owned trace.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.metas
                .iter()
                .map(|m| {
                    Json::obj(vec![
                        ("id", Json::num(m.id as f64)),
                        ("task", Json::num(m.task.index() as f64)),
                        ("user_input", Json::str(self.user_input(m).to_string())),
                        ("uil", Json::num(m.user_input_len as f64)),
                        ("len", Json::num(m.request_len as f64)),
                        ("gen", Json::num(m.gen_len as f64)),
                        ("arrival", Json::num(m.arrival)),
                    ])
                })
                .collect(),
        )
    }

    /// Parse a trace (old or new files — the schema never carried
    /// instruction text) directly into a store: instructions reconstruct
    /// from the task id via [`TaskId::instruction`], user inputs intern
    /// into the arena, and no owned `Request` is materialised.  Record
    /// parsing is shared with the owned deserialiser
    /// (`trace::parse_trace_record`), so the two cannot drift.
    pub fn from_json(j: &Json) -> anyhow::Result<TraceStore> {
        let arr = j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("trace: expected array"))?;
        // Exact arena size is already known from the parsed items.
        let bytes: usize = arr
            .iter()
            .map(|item| item.get("user_input").as_str().map_or(0, str::len))
            .sum();
        let mut store = TraceStore::with_capacity(arr.len(), bytes);
        for item in arr {
            let rec = crate::workload::trace::parse_trace_record(item)?;
            store.push(
                rec.id,
                rec.task,
                rec.task.instruction(),
                rec.user_input,
                rec.user_input_len,
                rec.request_len,
                rec.gen_len,
                rec.arrival,
            );
        }
        Ok(store)
    }
}

/// Streaming trace generator: Poisson arrivals over the weighted task mix
/// (exactly [`generate_trace`](crate::workload::generate_trace)'s model
/// and RNG sequence), yielding one [`RequestMeta`] at a time and writing
/// each text straight into the target store's arena.
pub struct StreamingTraceGen {
    spec: TraceSpec,
    rng: Rng,
    tok: Tokenizer,
    weights: Vec<f64>,
    t: f64,
    next: usize,
}

impl StreamingTraceGen {
    pub fn new(spec: &TraceSpec) -> StreamingTraceGen {
        let weights = if spec.task_weights.len() == TaskId::ALL.len() {
            spec.task_weights.clone()
        } else {
            vec![1.0; TaskId::ALL.len()]
        };
        StreamingTraceGen {
            spec: spec.clone(),
            rng: Rng::new(spec.seed),
            tok: Tokenizer::new(),
            weights,
            t: 0.0,
            next: 0,
        }
    }

    /// Requests not yet generated.
    pub fn remaining(&self) -> usize {
        self.spec.n_requests - self.next
    }

    /// Generate the next request into `store`; `None` once the spec's
    /// request count is exhausted.
    pub fn next_into(&mut self, store: &mut TraceStore) -> Option<RequestMeta> {
        if self.next >= self.spec.n_requests {
            return None;
        }
        self.t += self.rng.exponential(self.spec.rate);
        let task = TaskId::ALL[self.rng.weighted_index(&self.weights)];
        let shape = sample_shape(
            task,
            self.spec.llm,
            self.spec.g_max,
            self.spec.l_cap,
            &mut self.rng,
        );
        let instruction = task.instruction();
        // The probe is over a ≤ 8-entry table whose non-matching entries
        // fail on their first bytes — noise next to the text synthesis —
        // and stays correct however many stores one generator targets.
        let instr = store.intern_instruction(instruction);
        // Text is synthesised at its final arena address — the only copy.
        let start = store.arena.len() as u64;
        synth_input_into(
            task,
            shape.topic,
            shape.user_input_len,
            &mut self.rng,
            &mut store.arena,
        );
        let text_len = store.arena.len() - start as usize;
        let request_len = (self.tok.token_len(instruction) + text_len) as u32;
        let meta = store.record_meta(
            self.next as u64,
            task,
            instr,
            shape.user_input_len,
            request_len,
            shape.gen_len,
            self.t,
            start,
        );
        self.next += 1;
        Some(meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::workload::generate_trace;

    #[test]
    fn streaming_generation_matches_owned_generation() {
        let spec = TraceSpec {
            rate: 3.0,
            n_requests: 400,
            seed: 11,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let store = TraceStore::generate(&spec);
        assert_eq!(store.len(), owned.len());
        for (i, r) in owned.iter().enumerate() {
            let m = store.meta(i);
            assert_eq!(m.id, r.id);
            assert_eq!(m.task, r.task);
            assert_eq!(m.user_input_len, r.user_input_len);
            assert_eq!(m.request_len, r.request_len);
            assert_eq!(m.gen_len, r.gen_len);
            assert_eq!(m.arrival.to_bits(), r.arrival.to_bits());
            assert_eq!(store.user_input(&m), r.user_input);
            assert_eq!(store.instruction(&m), r.instruction);
        }
        // Arena holds exactly the concatenated inputs, nothing more.
        let bytes: usize = owned.iter().map(|r| r.user_input.len()).sum();
        assert_eq!(store.arena_bytes(), bytes);
        // Instructions deduplicated: at most one entry per task.
        assert!(store.instructions.len() <= TaskId::ALL.len());
    }

    #[test]
    fn interning_owned_trace_equals_streaming_store() {
        let spec = TraceSpec {
            rate: 5.0,
            n_requests: 150,
            seed: 23,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let a = TraceStore::generate(&spec);
        let b = TraceStore::from_requests(&owned);
        assert_eq!(a.metas(), b.metas());
        assert_eq!(a.arena, b.arena);
        assert_eq!(a.instructions, b.instructions);
    }

    #[test]
    fn arena_interning_roundtrips_every_sampled_text() {
        // Satellite property test: for random specs, every interned text
        // (and the materialised owned request) round-trips exactly.
        prop_check(12, |rng| {
            let spec = TraceSpec {
                rate: rng.range_f64(0.5, 20.0),
                n_requests: rng.range_usize(1, 120),
                l_cap: if rng.range_u64(0, 2) == 0 {
                    0
                } else {
                    rng.range_u64(8, 200) as u32
                },
                seed: rng.next_u64(),
                ..Default::default()
            };
            let owned = generate_trace(&spec);
            let store = TraceStore::generate(&spec);
            for (i, r) in owned.iter().enumerate() {
                let view = store.view(i);
                assert_eq!(view.user_input, r.user_input);
                assert_eq!(view.instruction, r.instruction);
                let back = store.request_of(&store.meta(i));
                assert_eq!(back.id, r.id);
                assert_eq!(back.task, r.task);
                assert_eq!(back.instruction, r.instruction);
                assert_eq!(back.user_input, r.user_input);
                assert_eq!(back.user_input_len, r.user_input_len);
                assert_eq!(back.request_len, r.request_len);
                assert_eq!(back.gen_len, r.gen_len);
                assert_eq!(back.arrival.to_bits(), r.arrival.to_bits());
            }
        });
    }

    #[test]
    fn json_roundtrip_via_store_matches_owned_schema() {
        let spec = TraceSpec {
            n_requests: 40,
            ..Default::default()
        };
        let store = TraceStore::generate(&spec);
        let owned = generate_trace(&spec);
        // The store emits the exact bytes the owned serialiser does (and
        // neither ever emits instruction text — deduped via the task id).
        let a = store.to_json().to_string();
        let b = crate::workload::trace_to_json(&owned).to_string();
        assert_eq!(a, b);
        assert!(!a.contains("Translate the following"));
        // And parses straight back into an identical store.
        let back = TraceStore::from_json(&Json::parse(&a).unwrap()).unwrap();
        assert_eq!(back.metas(), store.metas());
        assert_eq!(back.arena_bytes(), store.arena_bytes());
    }

    #[test]
    fn streaming_gen_is_resumable_mid_trace() {
        let spec = TraceSpec {
            n_requests: 60,
            seed: 5,
            ..Default::default()
        };
        let whole = TraceStore::generate(&spec);
        let mut store = TraceStore::new();
        let mut gen = StreamingTraceGen::new(&spec);
        let mut n = 0;
        while let Some(m) = gen.next_into(&mut store) {
            assert_eq!(m, whole.meta(n));
            n += 1;
            assert_eq!(gen.remaining(), spec.n_requests - n);
        }
        assert_eq!(n, 60);
        assert!(gen.next_into(&mut store).is_none());
    }

    #[test]
    fn detached_meta_carries_numbers_only() {
        let spec = TraceSpec {
            n_requests: 3,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let m = RequestMeta::detached(&owned[1]);
        assert_eq!(m.id, owned[1].id);
        assert_eq!(m.request_len, owned[1].request_len);
        assert_eq!(m.gen_len, owned[1].gen_len);
        // Both text addresses are sentinels: accidental resolution
        // panics (out of bounds) rather than aliasing a live store's
        // first instruction or yielding "".
        assert_eq!(m.instr, u32::MAX);
        assert_eq!(m.span, Span::DETACHED);
    }

    #[test]
    #[should_panic]
    fn resolving_detached_instruction_against_store_panics() {
        let spec = TraceSpec {
            n_requests: 2,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let store = TraceStore::generate(&spec);
        let detached = RequestMeta::detached(&owned[0]);
        let _ = store.instruction(&detached);
    }

    #[test]
    #[should_panic]
    fn resolving_detached_user_input_against_store_panics() {
        let spec = TraceSpec {
            n_requests: 2,
            ..Default::default()
        };
        let owned = generate_trace(&spec);
        let store = TraceStore::generate(&spec);
        let detached = RequestMeta::detached(&owned[0]);
        let _ = store.user_input(&detached);
    }
}
