//! Workload synthesis for the LMaaS scenario: the six applications / eight
//! tasks of the paper's evaluation (§IV-A), request sampling with
//! Table-I-calibrated input-length↔generation-length correlation, Poisson
//! arrival traces, and the predictor train/test splits.

pub mod apps;
pub mod dataset;
pub mod request;
pub mod shard;
pub mod store;
pub mod trace;

pub use apps::{App, LlmProfile, TaskId};
pub use request::{PredictedRequest, Request, RequestMeta, RequestView, Span, StoreId};
pub use shard::{
    open_any, open_manifest, shard_store, write_sharded, LoadedTrace, ShardedTrace,
    MANIFEST_FILE, MANIFEST_FORMAT, MANIFEST_VERSION,
};
pub use store::{
    StreamingTraceGen, TraceSource, TraceStore, TRACE_HEADER_BYTES, TRACE_MAGIC,
    TRACE_META_BYTES, TRACE_VERSION,
};
pub use trace::{generate_trace, trace_from_json, trace_to_json, TraceSpec};
