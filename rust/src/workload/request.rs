//! Request model for the LMaaS scenario (paper §II-A).
//!
//! A request = instruction (identifies the application/task) + user input.
//! Lengths are in tokens of the byte-level tokenizer.  `gen_len` is the
//! ground-truth generation length: the coordinator must never read it for
//! scheduling decisions (only the engine, which "samples EOS" with it, and
//! the log database after serving may).

use crate::workload::apps::TaskId;

/// A single LMaaS request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique, monotonically increasing id.
    pub id: u64,
    /// Which application task produced it.
    pub task: TaskId,
    /// The application instruction text (prefix).
    pub instruction: String,
    /// The raw user input text.
    pub user_input: String,
    /// User input length in tokens (paper: "user input length", UIL).
    pub user_input_len: u32,
    /// Whole request length in tokens (instruction + user input + BOS).
    pub request_len: u32,
    /// Ground-truth generation length (tokens up to and incl. EOS).
    pub gen_len: u32,
    /// Arrival time in seconds since workload start.
    pub arrival: f64,
}

impl Request {
    /// L(p) in the paper's notation.
    #[inline]
    pub fn len(&self) -> u32 {
        self.request_len
    }

    /// G(p) in the paper's notation — ground truth, engine-only.
    #[inline]
    pub fn true_gen_len(&self) -> u32 {
        self.gen_len
    }
}

/// A request annotated with the predictor's output, as it flows through the
/// batcher/scheduler (the serving path sees `predicted_gen_len`, never
/// `request.gen_len`).
#[derive(Debug, Clone)]
pub struct PredictedRequest {
    pub request: Request,
    /// G'(p): predicted generation length, clamped to [1, G_max].
    pub predicted_gen_len: u32,
}

impl PredictedRequest {
    #[inline]
    pub fn len(&self) -> u32 {
        self.request.request_len
    }

    #[inline]
    pub fn predicted(&self) -> u32 {
        self.predicted_gen_len
    }
}
