//! Request model for the LMaaS scenario (paper §II-A).
//!
//! A request = instruction (identifies the application/task) + user input.
//! Lengths are in tokens of the byte-level tokenizer.  `gen_len` is the
//! ground-truth generation length: the coordinator must never read it for
//! scheduling decisions (only the engine, which "samples EOS" with it, and
//! the log database after serving may).
//!
//! Two representations coexist:
//!
//! * [`Request`] — the **owned** form: text in per-request heap `String`s.
//!   Kept for trace JSON round-trips, dataset builders, and as the
//!   reference representation the golden-equivalence suite replays
//!   (`sim::reference`).
//! * [`RequestMeta`] — the **compact**, `Copy` form the serving pipeline
//!   carries: numeric fields plus a [`Span`] into the owning
//!   [`TraceStore`](crate::workload::TraceStore)'s text arena and an index
//!   into its deduplicated instruction table.  Moving a request through
//!   arrival → batching → dispatch → logging copies a few machine words
//!   and never touches the heap.
//!
//! [`RequestView`] is the borrowed bridge between the two: everything a
//! text consumer (the predictor's feature pipeline, the real-compute
//! tokenizer) needs, resolved either from an owned `Request` or from a
//! store + meta without cloning.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::workload::apps::TaskId;

/// FNV-1a over the user-input bytes — the canonical content hash of a
/// request's user text.  Computed **once** at trace intern time (or
/// binary-trace decode, which walks the arena anyway) and carried on
/// [`RequestMeta`]/[`RequestView`] as `uih`, so per-predict consumers
/// (the feature cache, drift keying) never rehash the text.  Same FNV
/// constants as the hashed embedder; synthetic text-less metas use
/// `uih: 0` as the "no hash" sentinel (consumers skip caching on it).
#[inline]
pub fn hash_user_input(s: &str) -> u64 {
    hash_user_input_bytes(s.as_bytes())
}

/// [`hash_user_input`] over raw bytes — the in-place binary-trace meta
/// view hashes a span of the file-backed arena without first proving the
/// span is UTF-8 (FNV-1a is byte-defined, so the two entry points agree
/// on any text by construction).
#[inline]
pub fn hash_user_input_bytes(b: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in b {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Provenance stamp of a [`TraceStore`]: every live store mints a
/// process-unique id at construction and stamps it into each
/// [`RequestMeta`] it records; text resolution debug-asserts the stamp,
/// so a meta resolved against the *wrong* live store fails loudly
/// instead of silently aliasing that store's arena (a wrong-store span
/// that happens to be in range would otherwise return someone else's
/// text).
///
/// The stamp is runtime-only identity: it is **not** persisted in the
/// binary trace format (reopening a file mints a fresh id) and is
/// excluded from [`RequestMeta`]'s `PartialEq` (two stores interning the
/// same trace hold *equal* metas with *different* provenance).
///
/// [`TraceStore`]: crate::workload::TraceStore
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreId(u32);

impl StoreId {
    /// Sentinel of a meta with no backing store ([`RequestMeta::detached`]
    /// and synthetic test/bench metas).  Never minted for a live store,
    /// so the provenance debug-assert fires on any resolution attempt.
    pub const DETACHED: StoreId = StoreId(0);

    /// Mint a fresh process-unique store id (live stores only).
    pub fn mint() -> StoreId {
        static NEXT: AtomicU32 = AtomicU32::new(1);
        StoreId(NEXT.fetch_add(1, Ordering::Relaxed))
    }
}

/// Byte range of one request's user-input text inside a
/// [`TraceStore`](crate::workload::TraceStore) arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Byte offset of the text in the arena.
    pub start: u64,
    /// Byte length of the text.
    pub len: u32,
}

impl Span {
    /// Sentinel span of a record with no backing arena
    /// ([`RequestMeta::detached`] and synthetic test/bench metas): the
    /// out-of-range start makes resolving the user input against any
    /// live store panic (slice out of bounds) instead of silently
    /// yielding `""` — pair it with `instr: u32::MAX` so instruction
    /// resolution panics too rather than aliasing a store's entry 0.
    pub const DETACHED: Span = Span {
        start: u64::MAX,
        len: 0,
    };
}

/// A single LMaaS request (owned text).
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique, monotonically increasing id.
    pub id: u64,
    /// Which application task produced it.
    pub task: TaskId,
    /// The application instruction text (prefix).
    pub instruction: String,
    /// The raw user input text.
    pub user_input: String,
    /// User input length in tokens (paper: "user input length", UIL).
    pub user_input_len: u32,
    /// Whole request length in tokens (instruction + user input + BOS).
    pub request_len: u32,
    /// Ground-truth generation length (tokens up to and incl. EOS).
    pub gen_len: u32,
    /// Arrival time in seconds since workload start.
    pub arrival: f64,
}

impl Request {
    /// L(p) in the paper's notation.
    #[inline]
    pub fn len(&self) -> u32 {
        self.request_len
    }

    /// G(p) in the paper's notation — ground truth, engine-only.
    #[inline]
    pub fn true_gen_len(&self) -> u32 {
        self.gen_len
    }

    /// Borrowed full view (text included) of this request.
    #[inline]
    pub fn view(&self) -> RequestView<'_> {
        RequestView {
            id: self.id,
            task: self.task,
            instruction: &self.instruction,
            user_input: &self.user_input,
            user_input_len: self.user_input_len,
            request_len: self.request_len,
            gen_len: self.gen_len,
            arrival: self.arrival,
            uih: hash_user_input(&self.user_input),
        }
    }
}

/// The compact request record the pipeline carries: all numeric fields of
/// [`Request`] plus arena coordinates instead of owned text.  `Copy`, so
/// arrival, batching, dispatch and logging move it without allocation.
///
/// Text resolution goes through the [`TraceStore`](crate::workload::TraceStore)
/// that minted the record (`store.user_input(&meta)` /
/// `store.instruction(&meta)` / `store.view_of(&meta)`); a meta built via
/// [`RequestMeta::detached`] has no backing arena and must never be
/// resolved (engine/scheduler/test paths that read only numbers).
#[derive(Debug, Clone, Copy)]
pub struct RequestMeta {
    /// Unique, monotonically increasing id.
    pub id: u64,
    /// Which application task produced it.
    pub task: TaskId,
    /// Provenance: the store that minted this meta ([`StoreId::DETACHED`]
    /// when there is none).  Debug-asserted on every text resolution.
    pub store: StoreId,
    /// Index into the owning store's deduplicated instruction table.
    pub instr: u32,
    /// User input length in tokens.
    pub user_input_len: u32,
    /// Whole request length in tokens.
    pub request_len: u32,
    /// Ground-truth generation length — engine/log-only, as on `Request`.
    pub gen_len: u32,
    /// Arrival time in seconds since workload start.
    pub arrival: f64,
    /// User-input text location in the owning store's arena.
    pub span: Span,
    /// Content hash of the user-input text ([`hash_user_input`]),
    /// computed once when the text is interned; `0` on synthetic metas
    /// with no text.
    pub uih: u64,
}

impl PartialEq for RequestMeta {
    /// Content equality — the provenance stamp is deliberately excluded:
    /// two stores interning the same trace (streamed vs owned vs
    /// reopened from a file) hold equal metas even though each carries
    /// its own [`StoreId`].
    fn eq(&self, other: &Self) -> bool {
        self.id == other.id
            && self.task == other.task
            && self.instr == other.instr
            && self.user_input_len == other.user_input_len
            && self.request_len == other.request_len
            && self.gen_len == other.gen_len
            && self.arrival == other.arrival
            && self.span == other.span
            && self.uih == other.uih
    }
}

impl RequestMeta {
    /// L(p) in the paper's notation.
    #[inline]
    pub fn len(&self) -> u32 {
        self.request_len
    }

    /// G(p) — ground truth, engine-only.
    #[inline]
    pub fn true_gen_len(&self) -> u32 {
        self.gen_len
    }

    /// Numeric-only meta for an owned request, with NO backing arena.
    /// For paths that never resolve text: engine cost models,
    /// scheduler/batcher tests, the owned-reference sim's engine
    /// hand-off.  Both text addresses are out-of-range sentinels
    /// (`instr = u32::MAX`, [`Span::DETACHED`]), so accidentally
    /// resolving a detached meta against a live store panics instead of
    /// silently aliasing the store's first instruction or yielding `""`.
    pub fn detached(r: &Request) -> RequestMeta {
        RequestMeta {
            id: r.id,
            task: r.task,
            store: StoreId::DETACHED,
            instr: u32::MAX,
            user_input_len: r.user_input_len,
            request_len: r.request_len,
            gen_len: r.gen_len,
            arrival: r.arrival,
            span: Span::DETACHED,
            uih: hash_user_input(&r.user_input),
        }
    }
}

/// Borrowed view of one request: the numeric fields plus `&str` slices of
/// both texts.  This is what the predictor feature path consumes — built
/// either from an owned [`Request`] (`r.view()`, used by dataset training
/// and goldens) or zero-copy from a store + meta
/// (`store.view_of(&meta)`, the serving hot path).
#[derive(Debug, Clone, Copy)]
pub struct RequestView<'a> {
    pub id: u64,
    pub task: TaskId,
    pub instruction: &'a str,
    pub user_input: &'a str,
    pub user_input_len: u32,
    pub request_len: u32,
    pub gen_len: u32,
    pub arrival: f64,
    /// Interned content hash of `user_input` ([`hash_user_input`]); `0`
    /// when the source meta carried no hash.
    pub uih: u64,
}

impl<'a> From<&'a Request> for RequestView<'a> {
    #[inline]
    fn from(r: &'a Request) -> RequestView<'a> {
        r.view()
    }
}

/// A request annotated with the predictor's output, as it flows through the
/// batcher/scheduler (the serving path sees `predicted_gen_len`, never
/// `meta.gen_len`).  `Copy`: the whole pipeline record is a few machine
/// words — no `String` travels past admission.
#[derive(Debug, Clone, Copy)]
pub struct PredictedRequest {
    pub meta: RequestMeta,
    /// G'(p): predicted generation length, clamped to [1, G_max].
    pub predicted_gen_len: u32,
}

impl PredictedRequest {
    #[inline]
    pub fn len(&self) -> u32 {
        self.meta.request_len
    }

    #[inline]
    pub fn predicted(&self) -> u32 {
        self.predicted_gen_len
    }
}
