//! The six LMaaS applications / eight tasks of the paper's evaluation and
//! their synthetic request generators.
//!
//! **Substitution note (DESIGN.md §2).**  The paper builds requests from
//! WMT18 (MT), a GEC corpus (GC), ParaDetox (TD), CodeXGLUE (CT, CC) and
//! Break-It-Fix-It (BF) and measures generation lengths by running
//! ChatGLM-6B / Qwen-7B / Baichuan2-7B.  None of those corpora or models
//! are available here, so each task is modelled by
//!
//!   * an input-length distribution (log-normal, clipped), and
//!   * a generation-length model  G = a·UIL + b + topic_bias + ε,
//!     ε ~ N(0, σ(UIL)),
//!
//! with (a, b, σ) calibrated per task so the per-task Pearson coefficients
//! match Table I (0.77–0.996) and the qualitative relations of §III-B hold
//! (BF: G ≈ UIL; CC: G > UIL; CT c++→py: G < UIL; CT py→c++: G > UIL).
//! "Topics" give each request latent semantic structure that is visible in
//! the generated user-input *text* (topic-indicative vocabulary) and shifts
//! G — this is exactly the residual signal that lets the USIN predictor
//! beat INST in Table II, as in the paper.
//!
//! Three [`LlmProfile`]s perturb the task parameters the way switching the
//! backing LLM does in Table I.

use crate::util::Rng;

/// The six applications of Fig. 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum App {
    /// Multilingual machine translation.
    MT,
    /// Grammar correction.
    GC,
    /// Text detoxification.
    TD,
    /// Code translation.
    CT,
    /// Bug fixing.
    BF,
    /// Code comment.
    CC,
}

impl App {
    pub fn name(&self) -> &'static str {
        match self {
            App::MT => "MT",
            App::GC => "GC",
            App::TD => "TD",
            App::CT => "CT",
            App::BF => "BF",
            App::CC => "CC",
        }
    }

    pub const ALL: [App; 6] = [App::MT, App::GC, App::TD, App::CT, App::BF, App::CC];

    /// Position of this app in [`App::ALL`] — the stable cell index the
    /// drift detector and the per-app fault axes key on.
    pub fn index(&self) -> usize {
        match self {
            App::MT => 0,
            App::GC => 1,
            App::TD => 2,
            App::CT => 3,
            App::BF => 4,
            App::CC => 5,
        }
    }
}

/// The eight tasks (MT and CT have two directions each, §IV-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TaskId {
    MtEnDe,
    MtDeEn,
    Gc,
    Td,
    CtCppPy,
    CtPyCpp,
    Bf,
    Cc,
}

impl TaskId {
    pub const ALL: [TaskId; 8] = [
        TaskId::MtEnDe,
        TaskId::MtDeEn,
        TaskId::Gc,
        TaskId::Td,
        TaskId::CtCppPy,
        TaskId::CtPyCpp,
        TaskId::Bf,
        TaskId::Cc,
    ];

    pub fn app(&self) -> App {
        match self {
            TaskId::MtEnDe | TaskId::MtDeEn => App::MT,
            TaskId::Gc => App::GC,
            TaskId::Td => App::TD,
            TaskId::CtCppPy | TaskId::CtPyCpp => App::CT,
            TaskId::Bf => App::BF,
            TaskId::Cc => App::CC,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskId::MtEnDe => "MT-en-de",
            TaskId::MtDeEn => "MT-de-en",
            TaskId::Gc => "GC",
            TaskId::Td => "TD",
            TaskId::CtCppPy => "CT-cpp-py",
            TaskId::CtPyCpp => "CT-py-cpp",
            TaskId::Bf => "BF",
            TaskId::Cc => "CC",
        }
    }

    /// The application instruction prefixed to every request of this task —
    /// the application-level semantic signal the INST predictor embeds.
    pub fn instruction(&self) -> &'static str {
        match self {
            TaskId::MtEnDe => "Translate the following English text to German:",
            TaskId::MtDeEn => "Translate the following German text to English:",
            TaskId::Gc => "Correct the grammatical errors in the following text and output the corrected text:",
            TaskId::Td => "Rewrite the following text to remove toxic language while keeping its meaning:",
            TaskId::CtCppPy => "Translate the following C++ code to Python and output only the code:",
            TaskId::CtPyCpp => "Translate the following Python code to C++ and output only the code:",
            TaskId::Bf => "Fix bugs in the following code and output the fixed code:",
            TaskId::Cc => "Write a documentation comment for the following code:",
        }
    }

    pub fn index(&self) -> usize {
        TaskId::ALL.iter().position(|t| t == self).unwrap()
    }
}

/// The three LLMs of Table I, as perturbations of the task parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LlmProfile {
    ChatGlm6B,
    Qwen7BChat,
    Baichuan27BChat,
}

impl LlmProfile {
    pub const ALL: [LlmProfile; 3] = [
        LlmProfile::ChatGlm6B,
        LlmProfile::Qwen7BChat,
        LlmProfile::Baichuan27BChat,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            LlmProfile::ChatGlm6B => "ChatGLM-6B",
            LlmProfile::Qwen7BChat => "Qwen-7B-Chat",
            LlmProfile::Baichuan27BChat => "Baichuan2-7B-Chat",
        }
    }

    /// (slope multiplier, extra noise multiplier) — different LLMs phrase
    /// answers differently; the perturbation keeps Table I's per-model
    /// spread without changing orderings.
    fn perturb(&self) -> (f64, f64) {
        match self {
            LlmProfile::ChatGlm6B => (1.00, 1.00),
            LlmProfile::Qwen7BChat => (1.06, 0.95),
            LlmProfile::Baichuan27BChat => (0.94, 1.05),
        }
    }
}

/// Generation-length model parameters for one task.
#[derive(Debug, Clone)]
pub struct TaskParams {
    /// Slope a of G = a·UIL + b.
    pub slope: f64,
    /// Intercept b.
    pub intercept: f64,
    /// Noise scale: σ(UIL) = noise_frac · UIL + noise_base.
    pub noise_frac: f64,
    pub noise_base: f64,
    /// Input-length log-normal (mu, sigma) of the underlying normal.
    pub len_mu: f64,
    pub len_sigma: f64,
    /// Input-length clip range (tokens).
    pub len_min: u32,
    pub len_max: u32,
    /// Number of latent topics and the ± fraction they shift G by.
    pub n_topics: usize,
    pub topic_shift: f64,
}

/// Per-task calibrated parameters.
///
/// Targets (Table I, ChatGLM column): MT 0.967, GC 0.981, TD 0.778,
/// CT 0.996, BF 0.992, CC 0.771.  σ grows with UIL so that Pearson is
/// roughly scale-free; noise_frac is the knob that sets the coefficient.
pub fn task_params(task: TaskId) -> TaskParams {
    let base = TaskParams {
        slope: 1.0,
        intercept: 2.0,
        noise_frac: 0.05,
        noise_base: 2.0,
        len_mu: 4.8,
        len_sigma: 0.7,
        len_min: 6,
        len_max: 600,
        n_topics: 4,
        topic_shift: 0.06,
    };
    match task {
        TaskId::MtEnDe => TaskParams {
            slope: 1.08,
            intercept: 3.0,
            noise_frac: 0.075,
            ..base
        },
        TaskId::MtDeEn => TaskParams {
            slope: 0.93,
            intercept: 2.0,
            noise_frac: 0.075,
            ..base
        },
        TaskId::Gc => TaskParams {
            slope: 1.0,
            intercept: 1.0,
            noise_frac: 0.055,
            noise_base: 1.0,
            ..base
        },
        TaskId::Td => TaskParams {
            slope: 0.88,
            intercept: 2.0,
            noise_frac: 0.18,
            noise_base: 4.0,
            n_topics: 6,
            topic_shift: 0.55,
            ..base
        },
        TaskId::CtCppPy => TaskParams {
            slope: 0.62,
            intercept: 4.0,
            noise_frac: 0.025,
            len_mu: 4.9,
            len_sigma: 0.6,
            ..base
        },
        TaskId::CtPyCpp => TaskParams {
            slope: 1.45,
            intercept: 8.0,
            noise_frac: 0.025,
            len_mu: 4.7,
            len_sigma: 0.6,
            ..base
        },
        TaskId::Bf => TaskParams {
            slope: 1.02,
            intercept: 2.0,
            noise_frac: 0.035,
            len_mu: 4.8,
            len_sigma: 0.6,
            ..base
        },
        TaskId::Cc => TaskParams {
            slope: 1.6,
            intercept: 10.0,
            noise_frac: 0.26,
            noise_base: 6.0,
            len_mu: 4.7,
            len_sigma: 0.6,
            n_topics: 8,
            topic_shift: 0.62,
            ..base
        },
    }
}

/// Vocabulary used to synthesise user-input text per task topic.  Natural
/// tasks draw common words; code tasks draw identifier-ish tokens.  The
/// first word of a cluster acts as the topic marker that repeatedly shows
/// up, giving the hashed embedder a learnable signal.
const NATURAL_WORDS: [&str; 24] = [
    "the", "quick", "report", "market", "weather", "family", "music", "train",
    "garden", "coffee", "window", "letter", "bridge", "doctor", "evening",
    "history", "island", "journey", "kitchen", "library", "mountain", "news",
    "ocean", "painting",
];

const CODE_WORDS: [&str; 24] = [
    "int", "vec", "push_back", "return", "for", "while", "if", "else",
    "size", "begin", "end", "auto", "def", "self", "print", "range", "len",
    "append", "class", "void", "const", "static", "index", "buffer",
];

const TOPIC_MARKERS: [&str; 8] = [
    "finance", "sports", "travel", "health", "science", "politics", "art",
    "games",
];

/// Synthesise a user-input text of roughly `target_tokens` tokens
/// (byte-level tokenizer: 1 token per byte + BOS) for the given task/topic,
/// appending to `out` (the `TraceStore` arena on the streaming path — the
/// text is written once at its final address, no intermediate `String`).
/// Byte-for-byte and RNG-for-RNG identical to the owned [`synth_input`].
pub fn synth_input_into(
    task: TaskId,
    topic: usize,
    target_tokens: u32,
    rng: &mut Rng,
    out: &mut String,
) {
    let words: &[&str] = match task.app() {
        App::CT | App::BF | App::CC => &CODE_WORDS,
        _ => &NATURAL_WORDS,
    };
    let marker = TOPIC_MARKERS[topic % TOPIC_MARKERS.len()];
    let start = out.len();
    out.push_str(marker);
    while out.len() - start + 1 < target_tokens as usize {
        out.push(' ');
        // Re-mention the topic marker ~1/6 of the time so user-level
        // semantics are recoverable from hashed n-grams.
        if rng.f64() < 1.0 / 6.0 {
            out.push_str(marker);
        } else {
            out.push_str(words[rng.range_usize(0, words.len())]);
        }
    }
    // All-ASCII vocabulary, so byte truncation is char-safe.
    out.truncate(start + (target_tokens as usize).saturating_sub(1).max(1));
}

/// Synthesise a user-input text as an owned `String` (the pre-arena form;
/// dataset builders and the owned trace generator still use it).
pub fn synth_input(task: TaskId, topic: usize, target_tokens: u32, rng: &mut Rng) -> String {
    let mut s = String::with_capacity(target_tokens as usize + 16);
    synth_input_into(task, topic, target_tokens, rng, &mut s);
    s
}

/// One sampled request body (before arrival-time assignment).
#[derive(Debug, Clone)]
pub struct SampledRequest {
    pub task: TaskId,
    pub topic: usize,
    pub user_input: String,
    pub user_input_len: u32,
    pub gen_len: u32,
}

/// The numeric half of a sampled request — everything but the text.  The
/// streaming trace generator draws this first, then synthesises the text
/// straight into the arena (`synth_input_into`).
#[derive(Debug, Clone, Copy)]
pub struct SampledShape {
    pub task: TaskId,
    pub topic: usize,
    pub user_input_len: u32,
    pub gen_len: u32,
}

/// Draw the numeric shape of a request for `task` under `llm` — the exact
/// RNG prefix of [`sample_request`] (lognormal length, topic, gen noise),
/// with the text draw left to the caller.
pub fn sample_shape(
    task: TaskId,
    llm: LlmProfile,
    g_max: u32,
    l_cap: u32,
    rng: &mut Rng,
) -> SampledShape {
    let p = task_params(task);
    let (slope_mul, noise_mul) = llm.perturb();
    let len_max = if l_cap > 0 { l_cap.min(p.len_max) } else { p.len_max };

    let raw = rng.lognormal(p.len_mu, p.len_sigma);
    let uil = (raw.round() as u32).clamp(p.len_min, len_max);

    let topic = rng.range_usize(0, p.n_topics);
    // Topics alternate sign so the task-level mean stays put.
    let tshift = p.topic_shift * (topic as f64 - (p.n_topics - 1) as f64 / 2.0)
        / ((p.n_topics - 1).max(1) as f64 / 2.0);

    let sigma = (p.noise_frac * uil as f64 + p.noise_base) * noise_mul;
    let mean = p.slope * slope_mul * uil as f64 * (1.0 + tshift) + p.intercept;
    let g = rng.normal_ms(mean, sigma).round();
    let gen_len = (g.max(1.0) as u32).min(g_max);

    SampledShape {
        task,
        topic,
        user_input_len: uil,
        gen_len,
    }
}

/// Sample a request for `task` under `llm`, honoring the generation-length
/// cap `g_max` and input cap `l_cap` (0 = use task default).
pub fn sample_request(
    task: TaskId,
    llm: LlmProfile,
    g_max: u32,
    l_cap: u32,
    rng: &mut Rng,
) -> SampledRequest {
    let s = sample_shape(task, llm, g_max, l_cap, rng);
    let user_input = synth_input(task, s.topic, s.user_input_len, rng);
    SampledRequest {
        task,
        topic: s.topic,
        user_input,
        user_input_len: s.user_input_len,
        gen_len: s.gen_len,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::pearson;

    #[test]
    fn eight_tasks_six_apps() {
        assert_eq!(TaskId::ALL.len(), 8);
        let mut apps: Vec<App> = TaskId::ALL.iter().map(|t| t.app()).collect();
        apps.dedup();
        assert_eq!(
            TaskId::ALL.iter().map(|t| t.app()).collect::<std::collections::HashSet<_>>().len(),
            6
        );
        let _ = apps;
    }

    #[test]
    fn instructions_are_distinct() {
        let set: std::collections::HashSet<&str> =
            TaskId::ALL.iter().map(|t| t.instruction()).collect();
        assert_eq!(set.len(), 8);
    }

    #[test]
    fn synth_input_hits_target_length() {
        let mut rng = Rng::new(1);
        for &target in &[8u32, 50, 200, 600] {
            let s = synth_input(TaskId::Gc, 1, target, &mut rng);
            // token_len = bytes + BOS
            let tokens = s.len() as u32 + 1;
            assert!(
                tokens <= target + 1 && tokens + 12 >= target,
                "target={target} got={tokens}"
            );
        }
    }

    #[test]
    fn gen_len_capped_and_positive() {
        let mut rng = Rng::new(2);
        for _ in 0..2000 {
            let s = sample_request(TaskId::Cc, LlmProfile::ChatGlm6B, 128, 100, &mut rng);
            assert!(s.gen_len >= 1 && s.gen_len <= 128);
            assert!(s.user_input_len <= 100);
        }
    }

    #[test]
    fn pearson_matches_table1_band_per_task() {
        // Table I (ChatGLM-6B): MT .967 GC .981 TD .778 CT .996 BF .992 CC .771
        // Accept each task within ±0.08 of its target.
        let targets = [
            (TaskId::MtEnDe, 0.967),
            (TaskId::Gc, 0.981),
            (TaskId::Td, 0.778),
            (TaskId::CtCppPy, 0.996),
            (TaskId::Bf, 0.992),
            (TaskId::Cc, 0.771),
        ];
        let mut rng = Rng::new(3);
        for (task, want) in targets {
            let mut uil = Vec::new();
            let mut g = Vec::new();
            for _ in 0..2000 {
                let s = sample_request(task, LlmProfile::ChatGlm6B, 1024, 0, &mut rng);
                uil.push(s.user_input_len as f64);
                g.push(s.gen_len as f64);
            }
            let r = pearson(&uil, &g);
            assert!(
                (r - want).abs() < 0.08,
                "{}: pearson {r:.3}, want ~{want}",
                task.name()
            );
        }
    }

    #[test]
    fn qualitative_relations_hold() {
        // §III-B: BF G≈UIL, CC G>UIL, CT c++→py G<UIL, CT py→c++ G>UIL.
        let mut rng = Rng::new(4);
        let mut mean_ratio = |task| {
            let mut rsum = 0.0;
            let n = 1500;
            for _ in 0..n {
                let s = sample_request(task, LlmProfile::ChatGlm6B, 4096, 0, &mut rng);
                rsum += s.gen_len as f64 / s.user_input_len as f64;
            }
            rsum / n as f64
        };
        assert!((mean_ratio(TaskId::Bf) - 1.0).abs() < 0.15);
        assert!(mean_ratio(TaskId::Cc) > 1.3);
        assert!(mean_ratio(TaskId::CtCppPy) < 0.85);
        assert!(mean_ratio(TaskId::CtPyCpp) > 1.25);
    }

    #[test]
    fn llm_profiles_shift_but_preserve_order() {
        let mut rng = Rng::new(5);
        for llm in LlmProfile::ALL {
            let s = sample_request(TaskId::MtEnDe, llm, 1024, 0, &mut rng);
            assert!(s.gen_len >= 1);
        }
    }
}
