//! Multi-shard binary traces: one logical trace split across N `.mtr`
//! files plus a small JSON manifest, so a 10⁷–10⁸-request workload can
//! be generated with bounded memory (one shard resident at a time) and
//! replayed by a cluster with **one shard mapped per instance** instead
//! of every instance mapping the whole file.
//!
//! On disk a sharded trace is a directory:
//!
//! ```text
//! trace-dir/
//!   manifest.json      { format, version, total_requests, shards: [...] }
//!   shard-0000.mtr     requests [0, n₀)        — ordinary binary traces,
//!   shard-0001.mtr     requests [n₀, n₀+n₁)      openable on their own
//!   ...
//! ```
//!
//! Each manifest entry records the shard's file name, request count,
//! global start index, byte length and an FNV-1a checksum of its
//! 48-byte header.  [`open_manifest`] verifies all of that in O(shards)
//! — existence, length, checksum, header agreement, contiguous
//! non-overlapping in-order ranges — and opens every shard through the
//! O(1) lazy [`TraceStore`] open, so opening a sharded 10⁷-request
//! trace stays O(shards), not O(requests).  A corrupt manifest (missing
//! shard, checksum mismatch, overlapping or out-of-order ranges,
//! count drift) is an error, never a panic (`tests/trace_io.rs`).
//!
//! [`ShardedTrace`] presents the shards as one global index space and
//! implements [`TraceSource`], so every store-generic serving loop
//! replays it without concatenation; request ids and arrival times are
//! global (the streaming generator runs once across all shards), while
//! spans and instruction indices are shard-local.
//!
//! [`open_any`] is the single CLI entry for *any* trace argument: it
//! sniffs content — `MAGNUSTR` magic → binary, JSON array → legacy
//! trace, JSON manifest object or directory → sharded — so a binary
//! trace named `.json` and a JSON trace named `.mtr` both load (the
//! extension-based detection this replaces got both wrong).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::util::mmap::read_prefix;
use crate::util::Json;
use crate::workload::request::{hash_user_input_bytes, RequestMeta, RequestView};
use crate::workload::store::{TraceSource, TraceStore, TRACE_HEADER_BYTES, TRACE_MAGIC};
use crate::workload::trace::TraceSpec;
use crate::workload::StreamingTraceGen;

/// `format` field every shard manifest carries.
pub const MANIFEST_FORMAT: &str = "magnus-trace-manifest";
/// Manifest schema version this build writes and reads.
pub const MANIFEST_VERSION: u32 = 1;
/// File name of the manifest inside a sharded-trace directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// One logical trace, split across per-shard [`TraceStore`]s opened
/// from a manifest (or built by [`shard_store`]'s writer twin).  Shards
/// are `Arc`'d so a cluster can hand shard `i` to instance `i` without
/// cloning.
#[derive(Debug, Clone)]
pub struct ShardedTrace {
    shards: Vec<Arc<TraceStore>>,
    /// Global start index of each shard (strictly increasing,
    /// `starts[0] == 0`, contiguous).
    starts: Vec<usize>,
    total: usize,
}

impl ShardedTrace {
    /// Wrap already-opened shards (order = global order; counts define
    /// the global index space).  `open_manifest` is the file route.
    pub fn from_shards(shards: Vec<Arc<TraceStore>>) -> ShardedTrace {
        let mut starts = Vec::with_capacity(shards.len());
        let mut total = 0usize;
        for s in &shards {
            starts.push(total);
            total += s.len();
        }
        ShardedTrace {
            shards,
            starts,
            total,
        }
    }

    /// Number of requests across all shards.
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard `s` (instance `s`'s store under one-shard-per-instance
    /// mapping).
    pub fn shard(&self, s: usize) -> &Arc<TraceStore> {
        &self.shards[s]
    }

    /// All shards, in global order.
    pub fn shards(&self) -> &[Arc<TraceStore>] {
        &self.shards
    }

    /// Which shard holds global request `g`, and its local index there.
    #[inline]
    pub fn locate(&self, g: usize) -> (usize, usize) {
        assert!(g < self.total, "request {g} out of range ({} total)", self.total);
        let s = self.starts.partition_point(|&start| start <= g) - 1;
        (s, g - self.starts[s])
    }

    /// Run [`TraceStore::validate_all`] over every shard.
    pub fn validate_all(&self) -> anyhow::Result<()> {
        for (s, shard) in self.shards.iter().enumerate() {
            shard
                .validate_all()
                .map_err(|e| anyhow::anyhow!("shard {s}: {e}"))?;
        }
        Ok(())
    }
}

impl TraceSource for ShardedTrace {
    #[inline]
    fn len(&self) -> usize {
        self.total
    }

    #[inline]
    fn arrival(&self, g: usize) -> f64 {
        let (s, i) = self.locate(g);
        self.shards[s].arrival(i)
    }

    #[inline]
    fn meta(&self, g: usize) -> RequestMeta {
        let (s, i) = self.locate(g);
        self.shards[s].meta(i)
    }

    #[inline]
    fn view(&self, g: usize) -> RequestView<'_> {
        let (s, i) = self.locate(g);
        self.shards[s].view(i)
    }

    #[inline]
    fn view_of(&self, m: &RequestMeta) -> RequestView<'_> {
        // Metas carry shard-local spans plus the minting shard's
        // provenance stamp — resolve against that shard (failover and
        // work stealing move metas across instances, so the owner is
        // found by stamp, not by id range).
        let s = self
            .shards
            .iter()
            .position(|sh| sh.id() == m.store)
            .expect("meta resolved against a sharded trace that holds no shard minting it");
        self.shards[s].view_of(m)
    }

    #[inline]
    fn home_of(&self, g: usize) -> Option<usize> {
        Some(self.locate(g).0)
    }
}

/// Even split of `total` requests over `n_shards`: the first
/// `total % n_shards` shards carry one extra request, every shard is
/// non-empty when `total ≥ n_shards`.
fn shard_counts(total: usize, n_shards: usize) -> Vec<usize> {
    let base = total / n_shards;
    let extra = total % n_shards;
    (0..n_shards)
        .map(|k| base + usize::from(k < extra))
        .collect()
}

/// FNV-1a over a shard's fixed-size header — the manifest checksum.
/// Cheap to verify at open (48 bytes per shard) while catching the
/// realistic corruptions: a swapped file, a truncated rewrite, a shard
/// regenerated with a different request count.
fn header_fnv(header: &[u8]) -> u64 {
    hash_user_input_bytes(header)
}

/// Serialise one manifest shard entry.
fn shard_entry(file: &str, requests: usize, start: usize, bytes: usize, fnv: u64) -> Json {
    Json::obj(vec![
        ("file", Json::str(file.to_string())),
        ("requests", Json::num(requests as f64)),
        ("start", Json::num(start as f64)),
        ("bytes", Json::num(bytes as f64)),
        // Hex string: JSON numbers are f64 and would round a 64-bit
        // checksum.
        ("header_fnv64", Json::str(format!("{fnv:016x}"))),
    ])
}

fn write_manifest(dir: &Path, total: usize, entries: Vec<Json>) -> anyhow::Result<PathBuf> {
    let manifest = Json::obj(vec![
        ("format", Json::str(MANIFEST_FORMAT.to_string())),
        ("version", Json::num(f64::from(MANIFEST_VERSION))),
        ("total_requests", Json::num(total as f64)),
        ("shards", Json::Arr(entries)),
    ]);
    let path = dir.join(MANIFEST_FILE);
    std::fs::write(&path, manifest.to_string())
        .map_err(|e| anyhow::anyhow!("manifest write {}: {e}", path.display()))?;
    Ok(path)
}

/// Name of shard `k`'s file.
fn shard_file_name(k: usize) -> String {
    format!("shard-{k:04}.mtr")
}

/// Encode `shard`, write it as shard `k` under `dir`, and return its
/// manifest entry.
fn write_one_shard(dir: &Path, k: usize, start: usize, shard: &TraceStore) -> anyhow::Result<Json> {
    let name = shard_file_name(k);
    let bytes = shard.to_binary()?;
    let path = dir.join(&name);
    std::fs::write(&path, &bytes)
        .map_err(|e| anyhow::anyhow!("shard write {}: {e}", path.display()))?;
    let fnv = header_fnv(&bytes[..TRACE_HEADER_BYTES]);
    Ok(shard_entry(&name, shard.len(), start, bytes.len(), fnv))
}

/// Generate `spec` directly into `n_shards` shard files under `dir`
/// (created if missing), returning the manifest path.  Streaming: one
/// [`StreamingTraceGen`] runs across all shards — ids and arrivals are
/// the exact global sequence a single-file generation produces — and
/// peak memory is one shard, which is what makes 10⁷–10⁸-request
/// traces writable at all.
pub fn write_sharded(spec: &TraceSpec, n_shards: usize, dir: &Path) -> anyhow::Result<PathBuf> {
    anyhow::ensure!(n_shards > 0, "shard count must be ≥ 1");
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("shard dir {}: {e}", dir.display()))?;
    let counts = shard_counts(spec.n_requests, n_shards);
    // Same per-request arena headroom heuristic as `TraceStore::generate`.
    let per_request = if spec.l_cap > 0 {
        (spec.l_cap as usize).min(160)
    } else {
        160
    };
    let mut gen = StreamingTraceGen::new(spec);
    let mut entries = Vec::with_capacity(n_shards);
    let mut start = 0usize;
    for (k, &n_k) in counts.iter().enumerate() {
        let mut shard = TraceStore::with_capacity(n_k, n_k * per_request);
        for _ in 0..n_k {
            gen.next_into(&mut shard)
                .expect("generator exhausted before its spec count");
        }
        entries.push(write_one_shard(dir, k, start, &shard)?);
        start += n_k;
    }
    write_manifest(dir, spec.n_requests, entries)
}

/// Split an existing store into `n_shards` shard files under `dir`
/// (re-interning each range), returning the manifest path.  The test /
/// re-packing twin of [`write_sharded`].
pub fn shard_store(store: &TraceStore, n_shards: usize, dir: &Path) -> anyhow::Result<PathBuf> {
    anyhow::ensure!(n_shards > 0, "shard count must be ≥ 1");
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("shard dir {}: {e}", dir.display()))?;
    let counts = shard_counts(store.len(), n_shards);
    let mut entries = Vec::with_capacity(n_shards);
    let mut start = 0usize;
    for (k, &n_k) in counts.iter().enumerate() {
        let mut shard = TraceStore::with_capacity(n_k, 0);
        for g in start..start + n_k {
            let v = store.view(g);
            shard.push(
                v.id,
                v.task,
                v.instruction,
                v.user_input,
                v.user_input_len,
                v.request_len,
                v.gen_len,
                v.arrival,
            );
        }
        entries.push(write_one_shard(dir, k, start, &shard)?);
        start += n_k;
    }
    write_manifest(dir, store.len(), entries)
}

/// Open a sharded trace from its manifest file, verifying every entry
/// in O(shards): the shard file exists with the recorded length, its
/// 48-byte header matches the recorded checksum, its own header's
/// request count matches the manifest, and the global ranges are
/// contiguous, in order and non-overlapping.  Each shard then opens
/// through the O(1) lazy route.  Every failure is a structured error
/// naming the shard — never a panic.
pub fn open_manifest(path: &Path) -> anyhow::Result<ShardedTrace> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("manifest open {}: {e}", path.display()))?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("manifest {}: not JSON: {e}", path.display()))?;
    let at = path.display();
    anyhow::ensure!(
        j.get("format").as_str() == Some(MANIFEST_FORMAT),
        "manifest {at}: missing format field \"{MANIFEST_FORMAT}\""
    );
    let version = j.get("version").as_u64().unwrap_or(0);
    anyhow::ensure!(
        version == u64::from(MANIFEST_VERSION),
        "manifest {at}: unsupported manifest version {version} (this build reads {MANIFEST_VERSION})"
    );
    let total = j
        .get("total_requests")
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("manifest {at}: missing total_requests"))?;
    let entries = j
        .get("shards")
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("manifest {at}: missing shards array"))?;
    anyhow::ensure!(!entries.is_empty(), "manifest {at}: empty shards array");
    let dir = path.parent().unwrap_or_else(|| Path::new("."));

    let mut shards = Vec::with_capacity(entries.len());
    let mut running = 0usize;
    for (k, e) in entries.iter().enumerate() {
        let file = e
            .get("file")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("manifest {at}: shard {k}: missing file"))?;
        let requests = e
            .get("requests")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("manifest {at}: shard {k}: missing requests"))?;
        let start = e
            .get("start")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("manifest {at}: shard {k}: missing start"))?;
        let bytes = e
            .get("bytes")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("manifest {at}: shard {k}: missing bytes"))?;
        let fnv_hex = e
            .get("header_fnv64")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("manifest {at}: shard {k}: missing header_fnv64"))?;
        let fnv = u64::from_str_radix(fnv_hex, 16).map_err(|_| {
            anyhow::anyhow!("manifest {at}: shard {k}: bad header_fnv64 {fnv_hex:?}")
        })?;
        anyhow::ensure!(
            start == running,
            "manifest {at}: shard {k}: meta range starts at {start} but the previous shards \
             end at {running} (overlapping or out-of-order ranges)"
        );

        let fpath = dir.join(file);
        let len = std::fs::metadata(&fpath)
            .map_err(|e| {
                anyhow::anyhow!(
                    "manifest {at}: shard {k}: missing shard file {}: {e}",
                    fpath.display()
                )
            })?
            .len();
        anyhow::ensure!(
            len == bytes as u64,
            "manifest {at}: shard {k}: {} is {len} bytes but the manifest records {bytes}",
            fpath.display()
        );
        let header = read_prefix(&fpath, TRACE_HEADER_BYTES)
            .map_err(|e| anyhow::anyhow!("manifest {at}: shard {k}: {}: {e}", fpath.display()))?;
        anyhow::ensure!(
            header_fnv(&header) == fnv,
            "manifest {at}: shard {k}: {}: header checksum mismatch",
            fpath.display()
        );
        let shard = TraceStore::open_mmap(&fpath)
            .map_err(|e| anyhow::anyhow!("manifest {at}: shard {k}: {e}"))?;
        anyhow::ensure!(
            shard.len() == requests,
            "manifest {at}: shard {k}: {} holds {} requests but the manifest records {requests}",
            fpath.display(),
            shard.len()
        );
        shards.push(Arc::new(shard));
        running += requests;
    }
    anyhow::ensure!(
        running == total,
        "manifest {at}: shards cover {running} requests but total_requests is {total}"
    );
    Ok(ShardedTrace::from_shards(shards))
}

/// A trace loaded by [`open_any`]: one store, or a sharded set.
#[derive(Debug)]
pub enum LoadedTrace {
    Single(TraceStore),
    Sharded(ShardedTrace),
}

impl LoadedTrace {
    pub fn len(&self) -> usize {
        match self {
            LoadedTrace::Single(s) => s.len(),
            LoadedTrace::Sharded(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Unwrap a single-store trace, or fail with a message naming the
    /// consumer — entry points that replay one store (serve,
    /// serve-edge, pack-trace) cannot take a shard set.
    pub fn require_single(self, what: &str) -> anyhow::Result<TraceStore> {
        match self {
            LoadedTrace::Single(s) => Ok(s),
            LoadedTrace::Sharded(s) => anyhow::bail!(
                "{what} replays a single trace but was given a {}-shard manifest; \
                 pass one .mtr/.json file, or use serve-cluster to map shards to instances",
                s.n_shards()
            ),
        }
    }

    /// Shards behind this trace, as the cluster maps them: one `Arc` per
    /// shard, or the whole store as a single "shard".
    pub fn shard_stores(self) -> Vec<Arc<TraceStore>> {
        match self {
            LoadedTrace::Single(s) => vec![Arc::new(s)],
            LoadedTrace::Sharded(s) => s.shards,
        }
    }
}

impl TraceSource for LoadedTrace {
    #[inline]
    fn len(&self) -> usize {
        LoadedTrace::len(self)
    }

    #[inline]
    fn arrival(&self, i: usize) -> f64 {
        match self {
            LoadedTrace::Single(s) => TraceSource::arrival(s, i),
            LoadedTrace::Sharded(s) => s.arrival(i),
        }
    }

    #[inline]
    fn meta(&self, i: usize) -> RequestMeta {
        match self {
            LoadedTrace::Single(s) => TraceSource::meta(s, i),
            LoadedTrace::Sharded(s) => TraceSource::meta(s, i),
        }
    }

    #[inline]
    fn view(&self, i: usize) -> RequestView<'_> {
        match self {
            LoadedTrace::Single(s) => TraceSource::view(s, i),
            LoadedTrace::Sharded(s) => TraceSource::view(s, i),
        }
    }

    #[inline]
    fn view_of(&self, m: &RequestMeta) -> RequestView<'_> {
        match self {
            LoadedTrace::Single(s) => TraceSource::view_of(s, m),
            LoadedTrace::Sharded(s) => TraceSource::view_of(s, m),
        }
    }

    #[inline]
    fn home_of(&self, i: usize) -> Option<usize> {
        match self {
            LoadedTrace::Single(_) => None,
            LoadedTrace::Sharded(s) => s.home_of(i),
        }
    }
}

/// Open **any** trace argument by content, never by extension: a
/// directory (its `manifest.json`), a binary trace (`MAGNUSTR` magic —
/// whatever the file is named), a JSON shard manifest, or a JSON trace
/// array.  Anything else errors naming the format that was detected.
pub fn open_any(path: &Path) -> anyhow::Result<LoadedTrace> {
    if path.is_dir() {
        let manifest = path.join(MANIFEST_FILE);
        anyhow::ensure!(
            manifest.is_file(),
            "{} is a directory without a {MANIFEST_FILE} shard manifest",
            path.display()
        );
        return Ok(LoadedTrace::Sharded(open_manifest(&manifest)?));
    }
    let head = read_prefix(path, TRACE_MAGIC.len())
        .map_err(|e| anyhow::anyhow!("trace open {}: {e}", path.display()))?;
    if head == TRACE_MAGIC {
        return Ok(LoadedTrace::Single(TraceStore::open_mmap(path)?));
    }
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("trace open {}: {e}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| {
        anyhow::anyhow!(
            "{}: no {:?} magic and not JSON either ({e})",
            path.display(),
            std::str::from_utf8(&TRACE_MAGIC).unwrap()
        )
    })?;
    if j.get("format").as_str() == Some(MANIFEST_FORMAT) {
        return Ok(LoadedTrace::Sharded(open_manifest(path)?));
    }
    if j.as_arr().is_some() {
        let store = TraceStore::from_json(&j)
            .map_err(|e| anyhow::anyhow!("trace {}: {e}", path.display()))?;
        return Ok(LoadedTrace::Single(store));
    }
    anyhow::bail!(
        "{}: detected JSON, but neither a trace array nor a \"{MANIFEST_FORMAT}\" object",
        path.display()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "magnus_shard_{}_{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn shard_counts_are_even_and_exhaustive() {
        assert_eq!(shard_counts(10, 3), vec![4, 3, 3]);
        assert_eq!(shard_counts(9, 3), vec![3, 3, 3]);
        assert_eq!(shard_counts(2, 4), vec![1, 1, 0, 0]);
        assert_eq!(shard_counts(0, 2), vec![0, 0]);
    }

    #[test]
    fn sharded_generation_matches_single_store_views() {
        let spec = TraceSpec {
            n_requests: 137,
            seed: 41,
            rate: 4.0,
            ..Default::default()
        };
        let dir = temp_dir("gen");
        let manifest = write_sharded(&spec, 4, &dir).unwrap();
        let sharded = open_manifest(&manifest).unwrap();
        sharded.validate_all().unwrap();
        assert_eq!(sharded.n_shards(), 4);

        let single = TraceStore::generate(&spec);
        assert_eq!(sharded.len(), single.len());
        for g in 0..single.len() {
            let (a, b) = (sharded.view(g), single.view(g));
            assert_eq!(a.id, b.id);
            assert_eq!(a.task, b.task);
            assert_eq!(a.user_input, b.user_input);
            assert_eq!(a.instruction, b.instruction);
            assert_eq!(a.user_input_len, b.user_input_len);
            assert_eq!(a.request_len, b.request_len);
            assert_eq!(a.gen_len, b.gen_len);
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.uih, b.uih);
            assert_eq!(sharded.arrival(g).to_bits(), b.arrival.to_bits());
        }
        // Global→shard mapping is contiguous and home_of agrees.
        let (s_first, l_first) = sharded.locate(0);
        assert_eq!((s_first, l_first), (0, 0));
        assert_eq!(sharded.home_of(sharded.len() - 1), Some(3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_any_detects_all_four_shapes() {
        let spec = TraceSpec {
            n_requests: 25,
            seed: 8,
            ..Default::default()
        };
        let dir = temp_dir("detect");
        std::fs::create_dir_all(&dir).unwrap();
        let store = TraceStore::generate(&spec);

        // Binary magic wins whatever the extension says.
        let misnamed_bin = dir.join("trace.json");
        store.write_file(&misnamed_bin).unwrap();
        match open_any(&misnamed_bin).unwrap() {
            LoadedTrace::Single(s) => assert_eq!(s.len(), 25),
            _ => panic!("binary file detected as sharded"),
        }

        // JSON array loads under a .mtr name.
        let misnamed_json = dir.join("trace.mtr");
        std::fs::write(&misnamed_json, store.to_json().to_string()).unwrap();
        match open_any(&misnamed_json).unwrap() {
            LoadedTrace::Single(s) => assert_eq!(s.len(), 25),
            _ => panic!("JSON trace detected as sharded"),
        }

        // Directory and manifest-file routes agree.
        let sdir = dir.join("shards");
        let manifest = shard_store(&store, 2, &sdir).unwrap();
        assert_eq!(open_any(&sdir).unwrap().len(), 25);
        assert_eq!(open_any(&manifest).unwrap().len(), 25);

        // JSON that is neither shape errors, naming what was detected.
        let stray = dir.join("stray.json");
        std::fs::write(&stray, "{\"not\": \"a trace\"}").unwrap();
        let err = open_any(&stray).unwrap_err().to_string();
        assert!(err.contains("detected JSON"), "unexpected error: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn require_single_refuses_shards_with_a_hint() {
        let spec = TraceSpec {
            n_requests: 12,
            seed: 2,
            ..Default::default()
        };
        let dir = temp_dir("single");
        let manifest = write_sharded(&spec, 3, &dir).unwrap();
        let loaded = open_any(&manifest).unwrap();
        let err = loaded.require_single("serve").unwrap_err().to_string();
        assert!(err.contains("serve-cluster"), "unexpected error: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
