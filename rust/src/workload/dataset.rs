//! Train/test dataset builders for the two learned components (paper
//! §III-B, §IV-A: per task 2 000 training + 500 test requests for the
//! generation-length predictor; the serving-time estimator trains on
//! logged batch executions).

use crate::tokenizer::Tokenizer;
use crate::util::Rng;
use crate::workload::apps::{sample_request, LlmProfile, TaskId};
use crate::workload::request::Request;

/// A labelled predictor example (the request carries the label in
/// `gen_len`).
pub type Labelled = Request;

/// Build `n` labelled requests for one task (arrival = 0; ids sequential
/// from `id_base`).
pub fn build_task_dataset(
    task: TaskId,
    llm: LlmProfile,
    n: usize,
    g_max: u32,
    seed: u64,
    id_base: u64,
) -> Vec<Labelled> {
    let mut rng = Rng::new(seed ^ (task.index() as u64) << 32);
    let tok = Tokenizer::new();
    (0..n)
        .map(|i| {
            let s = sample_request(task, llm, g_max, 0, &mut rng);
            let instruction = task.instruction().to_string();
            let request_len =
                (tok.token_len(&instruction) + s.user_input.len()) as u32;
            Request {
                id: id_base + i as u64,
                task,
                instruction,
                user_input: s.user_input,
                user_input_len: s.user_input_len,
                request_len,
                gen_len: s.gen_len,
                arrival: 0.0,
            }
        })
        .collect()
}

/// The paper's predictor evaluation split: per task `n_train` + `n_test`.
pub struct PredictorSplit {
    pub train: Vec<Labelled>,
    pub test: Vec<Labelled>,
}

/// Build the 8-task split (paper: 2 000 train + 500 test per task).
pub fn build_predictor_split(
    llm: LlmProfile,
    n_train: usize,
    n_test: usize,
    g_max: u32,
    seed: u64,
) -> PredictorSplit {
    let mut train = Vec::with_capacity(n_train * TaskId::ALL.len());
    let mut test = Vec::with_capacity(n_test * TaskId::ALL.len());
    for (ti, task) in TaskId::ALL.iter().enumerate() {
        let all = build_task_dataset(
            *task,
            llm,
            n_train + n_test,
            g_max,
            seed.wrapping_add(1000 + ti as u64),
            (ti * (n_train + n_test)) as u64,
        );
        train.extend_from_slice(&all[..n_train]);
        test.extend_from_slice(&all[n_train..]);
    }
    train.shuffle_with(seed);
    PredictorSplit { train, test }
}

trait ShuffleWith {
    fn shuffle_with(&mut self, seed: u64);
}

impl ShuffleWith for Vec<Labelled> {
    fn shuffle_with(&mut self, seed: u64) {
        let mut rng = Rng::new(seed ^ 0x5475_4c45);
        rng.shuffle(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_sizes() {
        let s = build_predictor_split(LlmProfile::ChatGlm6B, 100, 25, 1024, 1);
        assert_eq!(s.train.len(), 800);
        assert_eq!(s.test.len(), 200);
    }

    #[test]
    fn split_covers_all_tasks() {
        let s = build_predictor_split(LlmProfile::ChatGlm6B, 50, 10, 1024, 2);
        for task in TaskId::ALL {
            assert!(s.train.iter().any(|r| r.task == task));
            assert!(s.test.iter().any(|r| r.task == task));
        }
    }

    #[test]
    fn deterministic() {
        let a = build_task_dataset(TaskId::Bf, LlmProfile::ChatGlm6B, 20, 1024, 3, 0);
        let b = build_task_dataset(TaskId::Bf, LlmProfile::ChatGlm6B, 20, 1024, 3, 0);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.user_input, y.user_input);
            assert_eq!(x.gen_len, y.gen_len);
        }
    }

    #[test]
    fn train_and_test_disjoint_inputs() {
        let s = build_predictor_split(LlmProfile::ChatGlm6B, 50, 10, 1024, 4);
        // ids are disjoint by construction
        let train_ids: std::collections::HashSet<u64> =
            s.train.iter().map(|r| r.id).collect();
        assert!(s.test.iter().all(|r| !train_ids.contains(&r.id)));
    }
}
