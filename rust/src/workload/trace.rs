//! Workload traces: Poisson arrivals over a task mix (paper §IV-A
//! "Workloads") plus JSON (de)serialisation so every figure regenerates
//! from the exact same trace.

use crate::tokenizer::Tokenizer;
use crate::util::{Json, Rng};
use crate::workload::apps::{sample_request, LlmProfile, TaskId};
use crate::workload::request::Request;

/// Trace generation parameters.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Mean request arrival rate (requests/second).
    pub rate: f64,
    /// Number of requests.
    pub n_requests: usize,
    /// LLM profile the generation lengths emulate.
    pub llm: LlmProfile,
    /// Max generation length cap (paper: 1024).
    pub g_max: u32,
    /// Cap on user-input token length (0 = task default; the real-engine
    /// e2e path uses a small cap to fit the tiny model's 256-token cache).
    pub l_cap: u32,
    /// Per-task arrival weights; uniform if empty.
    pub task_weights: Vec<f64>,
    /// Seed.
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            rate: 1.0,
            n_requests: 500,
            llm: LlmProfile::ChatGlm6B,
            g_max: 1024,
            l_cap: 0,
            task_weights: Vec::new(),
            seed: 7,
        }
    }
}

/// Generate a trace: exponential inter-arrivals at `spec.rate`, tasks drawn
/// from the weighted mix, request bodies from the per-task models.
pub fn generate_trace(spec: &TraceSpec) -> Vec<Request> {
    let mut rng = Rng::new(spec.seed);
    let tok = Tokenizer::new();
    let weights = if spec.task_weights.len() == TaskId::ALL.len() {
        spec.task_weights.clone()
    } else {
        vec![1.0; TaskId::ALL.len()]
    };

    let mut t = 0.0_f64;
    let mut out = Vec::with_capacity(spec.n_requests);
    for id in 0..spec.n_requests {
        t += rng.exponential(spec.rate);
        let task = TaskId::ALL[rng.weighted_index(&weights)];
        let s = sample_request(task, spec.llm, spec.g_max, spec.l_cap, &mut rng);
        let instruction = task.instruction().to_string();
        let request_len =
            (tok.token_len(&instruction) + s.user_input.len()) as u32;
        out.push(Request {
            id: id as u64,
            task,
            instruction,
            user_input: s.user_input,
            user_input_len: s.user_input_len,
            request_len,
            gen_len: s.gen_len,
            arrival: t,
        });
    }
    out
}

/// Serialise a trace to JSON (user-input text included: traces are
/// replayable through the real predictor which embeds the text).
///
/// Instruction text is deliberately **not** emitted: the `task` id stands
/// for it and [`TaskId::instruction`] reconstructs it on load, so the
/// per-task instruction is stored exactly once-per-trace (by id) instead
/// of once-per-request — the on-disk analogue of the `TraceStore` dedup.
/// Byte-identical to [`crate::workload::TraceStore::to_json`] (asserted
/// in the store's tests); kept as a direct loop so serialising an owned
/// trace performs no intermediate arena copy.
pub fn trace_to_json(reqs: &[Request]) -> Json {
    Json::Arr(
        reqs.iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::num(r.id as f64)),
                    ("task", Json::num(r.task.index() as f64)),
                    ("user_input", Json::str(r.user_input.clone())),
                    ("uil", Json::num(r.user_input_len as f64)),
                    ("len", Json::num(r.request_len as f64)),
                    ("gen", Json::num(r.gen_len as f64)),
                    ("arrival", Json::num(r.arrival)),
                ])
            })
            .collect(),
    )
}

/// One parsed trace-JSON record (user input borrowed from the JSON
/// value).  The single schema definition shared by the owned and store
/// deserialisers, so the two cannot drift on keys or defaults.
pub(crate) struct TraceRecord<'a> {
    pub id: u64,
    pub task: TaskId,
    pub user_input: &'a str,
    pub user_input_len: u32,
    pub request_len: u32,
    pub gen_len: u32,
    pub arrival: f64,
}

/// Parse one record of the trace JSON schema (see [`trace_to_json`]).
pub(crate) fn parse_trace_record(item: &Json) -> anyhow::Result<TraceRecord<'_>> {
    let task_idx = item
        .get("task")
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("trace: missing task"))?;
    let task = *TaskId::ALL
        .get(task_idx)
        .ok_or_else(|| anyhow::anyhow!("trace: bad task index"))?;
    Ok(TraceRecord {
        id: item.get("id").as_u64().unwrap_or(0),
        task,
        user_input: item.get("user_input").as_str().unwrap_or(""),
        user_input_len: item.get("uil").as_u64().unwrap_or(0) as u32,
        request_len: item.get("len").as_u64().unwrap_or(0) as u32,
        gen_len: item.get("gen").as_u64().unwrap_or(1) as u32,
        arrival: item.get("arrival").as_f64().unwrap_or(0.0),
    })
}

/// Parse a trace back from JSON (old and new files share the schema —
/// neither ever carried instruction text; instructions reconstruct from
/// the task id).  [`crate::workload::TraceStore::from_json`] is the
/// zero-materialisation route for the serving path.
pub fn trace_from_json(j: &Json) -> anyhow::Result<Vec<Request>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("trace: expected array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let rec = parse_trace_record(item)?;
        out.push(Request {
            id: rec.id,
            task: rec.task,
            instruction: rec.task.instruction().to_string(),
            user_input: rec.user_input.to_string(),
            user_input_len: rec.user_input_len,
            request_len: rec.request_len,
            gen_len: rec.gen_len,
            arrival: rec.arrival,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_sorted_and_rate_is_right() {
        let spec = TraceSpec {
            rate: 4.0,
            n_requests: 4000,
            ..Default::default()
        };
        let trace = generate_trace(&spec);
        assert_eq!(trace.len(), 4000);
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let span = trace.last().unwrap().arrival;
        let rate = trace.len() as f64 / span;
        assert!((rate - 4.0).abs() < 0.3, "rate={rate}");
    }

    #[test]
    fn deterministic_for_same_seed() {
        let spec = TraceSpec {
            n_requests: 50,
            ..Default::default()
        };
        let a = generate_trace(&spec);
        let b = generate_trace(&spec);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.user_input, y.user_input);
            assert_eq!(x.gen_len, y.gen_len);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn json_roundtrip() {
        let spec = TraceSpec {
            n_requests: 30,
            ..Default::default()
        };
        let trace = generate_trace(&spec);
        let j = trace_to_json(&trace);
        let back = trace_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(trace.len(), back.len());
        for (x, y) in trace.iter().zip(&back) {
            assert_eq!(x.task, y.task);
            assert_eq!(x.user_input, y.user_input);
            assert_eq!(x.request_len, y.request_len);
            assert_eq!(x.gen_len, y.gen_len);
        }
    }

    #[test]
    fn json_carries_task_id_not_instruction_text() {
        // Satellite: instructions are stored by task id, never as text —
        // loading reconstructs them via `TaskId::instruction()`.
        let trace = generate_trace(&TraceSpec {
            n_requests: 16,
            ..Default::default()
        });
        let text = trace_to_json(&trace).to_string();
        assert!(!text.contains("instruction"));
        for t in TaskId::ALL {
            assert!(!text.contains(t.instruction()));
        }
        let back = trace_from_json(&Json::parse(&text).unwrap()).unwrap();
        for (x, y) in trace.iter().zip(&back) {
            assert_eq!(x.instruction, y.instruction);
        }
    }

    #[test]
    fn task_weights_respected() {
        let mut w = vec![0.0; 8];
        w[2] = 1.0; // only GC
        let spec = TraceSpec {
            n_requests: 100,
            task_weights: w,
            ..Default::default()
        };
        let trace = generate_trace(&spec);
        assert!(trace.iter().all(|r| r.task == TaskId::Gc));
    }

    #[test]
    fn l_cap_respected() {
        let spec = TraceSpec {
            n_requests: 300,
            l_cap: 64,
            ..Default::default()
        };
        let trace = generate_trace(&spec);
        assert!(trace.iter().all(|r| r.user_input_len <= 64));
    }

    #[test]
    fn request_len_covers_instruction_plus_input() {
        let spec = TraceSpec {
            n_requests: 20,
            ..Default::default()
        };
        for r in generate_trace(&spec) {
            assert!(r.request_len > r.user_input_len);
        }
    }
}
