//! Hand-rolled, std-only HTTP/1.1 (ISSUE 7 tentpole, in the spirit of
//! PR 5's `util/mmap.rs`: no crates, one narrow well-tested slice of the
//! protocol).
//!
//! [`wire`] is the byte layer — bounded request parsing (head and body
//! size limits, content-length only, no chunked encoding), response
//! serialization, and a minimal client-side response reader for the load
//! generator.  [`server`] is the connection layer — a non-blocking
//! accept loop, per-connection threads with read/write timeouts and a
//! connection cap, keep-alive, and graceful-shutdown drain.
//!
//! Deliberately *not* supported (the edge needs none of it): chunked
//! transfer encoding, HTTP/1.0 semantics, multi-line headers, pipelined
//! requests racing ahead of their responses, TLS.  Anything outside the
//! supported slice is rejected with an explicit 400, never mis-parsed.

pub mod server;
pub mod wire;

pub use server::{HttpConfig, HttpServer};
pub use wire::{read_request, read_response, HttpRequest, HttpResponse, ParseError};
