//! HTTP/1.1 byte layer: bounded request parsing and response
//! serialization over any `Read`/`Write`.
//!
//! Every input path is bounded: the request head (request line +
//! headers) may not exceed [`MAX_HEAD_BYTES`], the body may not exceed
//! [`MAX_BODY_BYTES`], and a declared `Content-Length` above the cap is
//! rejected *before* any body byte is read, so a hostile client cannot
//! make the server buffer unbounded memory.  Malformed input is an
//! explicit [`ParseError`], never a panic — the property tests below
//! drive random and truncated bytes through the parser to hold that
//! line.

use std::io::{Read, Write};

/// Upper bound on the request line + headers (bytes, CRLFs included).
pub const MAX_HEAD_BYTES: usize = 8 * 1024;
/// Upper bound on a request body we are willing to buffer.
pub const MAX_BODY_BYTES: usize = 64 * 1024;

/// One parsed request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Header names are kept as received; lookup is case-insensitive.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (first match).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Does the client ask us to close after this exchange?
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }
}

/// Why a read/parse failed.
#[derive(Debug)]
pub enum ParseError {
    /// Protocol violation (bad request line, bad header, bad length…).
    Malformed(&'static str),
    /// Head or declared body larger than the bound.
    TooLarge(&'static str),
    /// The peer closed mid-message (after at least one byte arrived).
    Incomplete,
    /// Transport error (includes read timeouts).
    Io(std::io::Error),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::Malformed(m) => write!(f, "malformed request: {m}"),
            ParseError::TooLarge(m) => write!(f, "request too large: {m}"),
            ParseError::Incomplete => write!(f, "peer closed mid-request"),
            ParseError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ParseError {}

/// Read one request off `r`.  `Ok(None)` means the peer closed cleanly
/// *before* sending any byte (the normal end of a keep-alive
/// connection); every other early close is [`ParseError::Incomplete`].
pub fn read_request<R: Read>(r: &mut R) -> Result<Option<HttpRequest>, ParseError> {
    let head = match read_head(r)? {
        Some(h) => h,
        None => return Ok(None),
    };
    let text = std::str::from_utf8(&head).map_err(|_| ParseError::Malformed("head not UTF-8"))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().ok_or(ParseError::Malformed("empty head"))?;
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(ParseError::Malformed("bad method"));
    }
    if path.is_empty() || !path.starts_with('/') {
        return Err(ParseError::Malformed("bad path"));
    }
    if version != "HTTP/1.1" || parts.next().is_some() {
        return Err(ParseError::Malformed("bad version"));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue; // trailing split artifact after the final CRLF
        }
        let (k, v) = line.split_once(':').ok_or(ParseError::Malformed("bad header line"))?;
        if k.is_empty() || k.contains(' ') {
            return Err(ParseError::Malformed("bad header name"));
        }
        headers.push((k.to_string(), v.trim().to_string()));
    }
    let req = HttpRequest {
        method,
        path,
        headers,
        body: Vec::new(),
    };
    if req
        .header("transfer-encoding")
        .map(|v| !v.eq_ignore_ascii_case("identity"))
        .unwrap_or(false)
    {
        return Err(ParseError::Malformed("chunked encoding not supported"));
    }
    let len = match req.header("content-length") {
        None => 0usize,
        Some(v) => v.trim().parse::<usize>().map_err(|_| ParseError::Malformed("bad content-length"))?,
    };
    if len > MAX_BODY_BYTES {
        return Err(ParseError::TooLarge("body"));
    }
    let mut body = vec![0u8; len];
    read_exact_or_incomplete(r, &mut body)?;
    Ok(Some(HttpRequest { body, ..req }))
}

/// Read bytes up to and including the `\r\n\r\n` head terminator,
/// returning the head *without* the terminator.  Byte-at-a-time reads
/// are fine here: callers wrap sockets in `BufReader`.
fn read_head<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>, ParseError> {
    let mut head: Vec<u8> = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => {
                return if head.is_empty() {
                    Ok(None)
                } else {
                    Err(ParseError::Incomplete)
                };
            }
            Ok(_) => {
                head.push(byte[0]);
                if head.len() > MAX_HEAD_BYTES {
                    return Err(ParseError::TooLarge("head"));
                }
                if head.ends_with(b"\r\n\r\n") {
                    head.truncate(head.len() - 4);
                    return Ok(Some(head));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(ParseError::Io(e)),
        }
    }
}

fn read_exact_or_incomplete<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<(), ParseError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Err(ParseError::Incomplete),
        Err(e) => Err(ParseError::Io(e)),
    }
}

/// One response to serialize.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Emit `Connection: close` and let the server drop the connection.
    pub close: bool,
}

impl HttpResponse {
    pub fn new(status: u16) -> HttpResponse {
        HttpResponse {
            status,
            headers: Vec::new(),
            body: Vec::new(),
            close: false,
        }
    }

    pub fn text(status: u16, body: &str) -> HttpResponse {
        let mut r = HttpResponse::new(status);
        r.headers.push(("Content-Type".into(), "text/plain; charset=utf-8".into()));
        r.body = body.as_bytes().to_vec();
        r
    }

    pub fn json(status: u16, body: String) -> HttpResponse {
        let mut r = HttpResponse::new(status);
        r.headers.push(("Content-Type".into(), "application/json".into()));
        r.body = body.into_bytes();
        r
    }

    pub fn closing(mut self) -> HttpResponse {
        self.close = true;
        self
    }

    /// Canonical reason phrase for the statuses the edge emits.
    pub fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Unknown",
        }
    }

    /// Serialize onto `w` (always emits `Content-Length`).
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Length: {}\r\n",
            self.status,
            Self::reason(self.status),
            self.body.len()
        );
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        if self.close {
            head.push_str("Connection: close\r\n");
        }
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Client-side: read one response off `r` and return `(status, body)`.
/// Used by the load generator; same bounds as the server side.
pub fn read_response<R: Read>(r: &mut R) -> Result<(u16, Vec<u8>), ParseError> {
    let head = read_head(r)?.ok_or(ParseError::Incomplete)?;
    let text = std::str::from_utf8(&head).map_err(|_| ParseError::Malformed("head not UTF-8"))?;
    let mut lines = text.split("\r\n");
    let status_line = lines.next().ok_or(ParseError::Malformed("empty head"))?;
    let mut parts = status_line.split(' ');
    if parts.next() != Some("HTTP/1.1") {
        return Err(ParseError::Malformed("bad version"));
    }
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or(ParseError::Malformed("bad status"))?;
    let mut len = 0usize;
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once(':').ok_or(ParseError::Malformed("bad header line"))?;
        if k.eq_ignore_ascii_case("content-length") {
            len = v.trim().parse().map_err(|_| ParseError::Malformed("bad content-length"))?;
        }
    }
    if len > MAX_BODY_BYTES {
        return Err(ParseError::TooLarge("body"));
    }
    let mut body = vec![0u8; len];
    read_exact_or_incomplete(r, &mut body)?;
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use std::io::Cursor;

    fn parse(bytes: &[u8]) -> Result<Option<HttpRequest>, ParseError> {
        read_request(&mut Cursor::new(bytes))
    }

    #[test]
    fn parses_post_with_body_and_headers() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\n\
                    X-Test: a b\r\n\r\nhello world";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("X-TEST"), Some("a b"));
        assert_eq!(req.body, b"hello world");
        assert!(!req.wants_close());
    }

    #[test]
    fn parses_get_without_body_and_connection_close() {
        let raw = b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n";
        let req = parse(raw).unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert!(req.wants_close());
    }

    #[test]
    fn clean_eof_is_none_truncation_is_incomplete() {
        assert!(parse(b"").unwrap().is_none());
        assert!(matches!(parse(b"GET / HT"), Err(ParseError::Incomplete)));
        assert!(matches!(
            parse(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"),
            Err(ParseError::Incomplete)
        ));
    }

    #[test]
    fn rejects_malformed_requests() {
        for raw in [
            &b"FLOOP\r\n\r\n"[..],
            b"GET  HTTP/1.1\r\n\r\n",
            b"GET noslash HTTP/1.1\r\n\r\n",
            b"GET / HTTP/1.0\r\n\r\n",
            b"GET / HTTP/1.1 extra\r\n\r\n",
            b"get / HTTP/1.1\r\n\r\n",
            b"GET / HTTP/1.1\r\nNoColonHere\r\n\r\n",
            b"GET / HTTP/1.1\r\nBad Name: v\r\n\r\n",
            b"POST / HTTP/1.1\r\nContent-Length: banana\r\n\r\n",
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
            b"GET / HTTP/1.1\r\n\xff\xfe\r\n\r\n",
        ] {
            assert!(
                matches!(parse(raw), Err(ParseError::Malformed(_))),
                "should reject {:?}",
                String::from_utf8_lossy(raw)
            );
        }
    }

    #[test]
    fn bounds_head_and_body() {
        let mut huge_head = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..2000 {
            huge_head.extend_from_slice(format!("X-H{i}: padpadpad\r\n").as_bytes());
        }
        huge_head.extend_from_slice(b"\r\n");
        assert!(matches!(parse(&huge_head), Err(ParseError::TooLarge("head"))));
        // oversized declared body is rejected before reading it
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(matches!(parse(raw.as_bytes()), Err(ParseError::TooLarge("body"))));
    }

    #[test]
    fn response_roundtrips_through_client_reader() {
        let mut buf = Vec::new();
        HttpResponse::json(429, "{\"err\":\"shed\"}".into()).write_to(&mut buf).unwrap();
        let (status, body) = read_response(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(status, 429);
        assert_eq!(body, b"{\"err\":\"shed\"}");
        // two pipelined responses on one stream read back in order
        let mut two = Vec::new();
        HttpResponse::text(200, "a").write_to(&mut two).unwrap();
        HttpResponse::text(503, "bb").closing().write_to(&mut two).unwrap();
        let mut c = Cursor::new(&two);
        assert_eq!(read_response(&mut c).unwrap(), (200, b"a".to_vec()));
        assert_eq!(read_response(&mut c).unwrap(), (503, b"bb".to_vec()));
    }

    /// Random bytes and random truncations of a valid request must never
    /// panic — they parse, or they fail with a typed error.
    #[test]
    fn prop_parser_is_total_on_garbage_and_truncations() {
        prop_check(300, |rng| {
            let n = rng.range_usize(0, 200);
            let garbage: Vec<u8> = (0..n).map(|_| rng.range_u64(0, 256) as u8).collect();
            let _ = parse(&garbage); // any Ok/Err is fine; no panic
            let body_len = rng.range_usize(0, 50);
            let body: String = (0..body_len).map(|_| 'x').collect();
            let valid = format!(
                "POST /v1/generate HTTP/1.1\r\nHost: h\r\nContent-Length: {body_len}\r\n\r\n{body}"
            );
            let cut = rng.range_usize(0, valid.len() + 1);
            match parse(&valid.as_bytes()[..cut]) {
                Ok(Some(req)) => {
                    assert_eq!(cut, valid.len(), "full parse only at full length");
                    assert_eq!(req.body.len(), body_len);
                }
                Ok(None) => assert_eq!(cut, 0, "clean EOF only with zero bytes"),
                Err(_) => assert!(cut < valid.len(), "valid bytes must parse"),
            }
        });
    }
}
