//! Connection layer: a threaded HTTP/1.1 server with bounded resources
//! and a graceful shutdown drain.
//!
//! One non-blocking accept thread hands each connection to its own
//! thread (the handler blocks on the edge's reply channel, so threads —
//! not an event loop — are the simple correct shape at this scale).
//! Resource bounds, because the edge must degrade instead of falling
//! over:
//!
//! * a **connection cap** — beyond it, new connections get an immediate
//!   `503` and are closed, which is load-shedding, not failure;
//! * **read/write timeouts** on every socket — a slow or dead client
//!   costs one thread for at most the timeout, never forever;
//! * **keep-alive** with per-request re-check of the shutdown flag — a
//!   draining server finishes the request in hand, answers with
//!   `Connection: close`, and lets the socket go.

use std::io::{BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::wire::{read_request, HttpRequest, HttpResponse, ParseError};

/// Connection-layer tunables.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address; port 0 picks a free port (tests/benches).
    pub addr: String,
    pub read_timeout: Duration,
    pub write_timeout: Duration,
    /// Concurrent-connection cap; excess connections are 503'd.
    pub max_connections: usize,
    /// How long `shutdown` waits for in-flight connections to finish.
    pub drain_grace: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(10),
            max_connections: 256,
            drain_grace: Duration::from_secs(10),
        }
    }
}

/// Request → response. Implemented for plain closures.
pub trait HttpHandler: Send + Sync + 'static {
    fn handle(&self, req: HttpRequest) -> HttpResponse;
}

impl<F> HttpHandler for F
where
    F: Fn(HttpRequest) -> HttpResponse + Send + Sync + 'static,
{
    fn handle(&self, req: HttpRequest) -> HttpResponse {
        self(req)
    }
}

/// Counters the accept/connection threads keep (all lock-free; the
/// edge's `/metrics` endpoint reads them live).
#[derive(Debug, Default)]
pub struct HttpStats {
    pub accepted: AtomicU64,
    /// Connections 503'd at the door because the cap was reached.
    pub over_cap: AtomicU64,
    /// Requests that failed to parse (400'd or unanswerable).
    pub bad_requests: AtomicU64,
    /// Connections reaped by a read timeout or transport error.
    pub reaped: AtomicU64,
    pub live: AtomicUsize,
}

pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    stats: Arc<HttpStats>,
    drain_grace: Duration,
}

impl HttpServer {
    /// Bind and start serving `handler` on a background accept thread.
    pub fn start<H: HttpHandler>(cfg: HttpConfig, handler: Arc<H>) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(HttpStats::default());
        let accept_thread = {
            let shutdown = Arc::clone(&shutdown);
            let stats = Arc::clone(&stats);
            let cfg = cfg.clone();
            std::thread::spawn(move || accept_loop(listener, cfg, handler, shutdown, stats))
        };
        Ok(HttpServer {
            addr,
            shutdown,
            accept_thread: Some(accept_thread),
            stats,
            drain_grace: cfg.drain_grace,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> &Arc<HttpStats> {
        &self.stats
    }

    /// Stop accepting, then wait (bounded by `drain_grace`) for live
    /// connections to finish their request in hand.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        let deadline = std::time::Instant::now() + self.drain_grace;
        while self.stats.live.load(Ordering::SeqCst) > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop<H: HttpHandler>(
    listener: TcpListener,
    cfg: HttpConfig,
    handler: Arc<H>,
    shutdown: Arc<AtomicBool>,
    stats: Arc<HttpStats>,
) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stats.accepted.fetch_add(1, Ordering::Relaxed);
                if stats.live.load(Ordering::SeqCst) >= cfg.max_connections {
                    stats.over_cap.fetch_add(1, Ordering::Relaxed);
                    refuse_over_cap(stream, &cfg);
                    continue;
                }
                stats.live.fetch_add(1, Ordering::SeqCst);
                let handler = Arc::clone(&handler);
                let shutdown = Arc::clone(&shutdown);
                let stats = Arc::clone(&stats);
                let cfg = cfg.clone();
                std::thread::spawn(move || {
                    serve_connection(stream, &cfg, handler, shutdown, &stats);
                    stats.live.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// Explicit shed at the door: the client hears `503`, not a RST.
fn refuse_over_cap(stream: TcpStream, cfg: &HttpConfig) {
    let _ = stream.set_write_timeout(Some(cfg.write_timeout));
    let mut s = stream;
    let _ = HttpResponse::text(503, "connection limit reached").closing().write_to(&mut s);
    let _ = s.shutdown(Shutdown::Both);
}

fn serve_connection<H: HttpHandler>(
    stream: TcpStream,
    cfg: &HttpConfig,
    handler: Arc<H>,
    shutdown: Arc<AtomicBool>,
    stats: &HttpStats,
) {
    if stream.set_read_timeout(Some(cfg.read_timeout)).is_err()
        || stream.set_write_timeout(Some(cfg.write_timeout)).is_err()
    {
        return;
    }
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut writer = write_half;
    let mut reader = BufReader::new(stream);
    loop {
        match read_request(&mut reader) {
            Ok(None) => return, // clean keep-alive close
            Ok(Some(req)) => {
                let client_close = req.wants_close();
                let mut resp = handler.handle(req);
                // Draining or client-requested close: answer, then drop.
                let closing = client_close || shutdown.load(Ordering::SeqCst);
                resp.close = resp.close || closing;
                let close_after = resp.close;
                if resp.write_to(&mut writer).is_err() {
                    stats.reaped.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                if close_after {
                    let _ = writer.flush();
                    let _ = reader.get_ref().shutdown(Shutdown::Both);
                    return;
                }
            }
            Err(ParseError::Malformed(m)) | Err(ParseError::TooLarge(m)) => {
                stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                let _ = HttpResponse::text(400, m).closing().write_to(&mut writer);
                let _ = reader.get_ref().shutdown(Shutdown::Both);
                return;
            }
            Err(ParseError::Incomplete) => {
                // Peer died mid-request (or a chaos conn-drop): nothing
                // to answer; reap the socket.
                stats.reaped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            Err(ParseError::Io(_)) => {
                // Read timeout or transport error: the slow-client bound.
                stats.reaped.fetch_add(1, Ordering::Relaxed);
                let _ = reader.get_ref().shutdown(Shutdown::Both);
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::wire::read_response;
    use std::io::Write as _;

    fn echo_server(max_conn: usize) -> HttpServer {
        let cfg = HttpConfig {
            read_timeout: Duration::from_millis(400),
            write_timeout: Duration::from_millis(400),
            max_connections: max_conn,
            drain_grace: Duration::from_secs(2),
            ..Default::default()
        };
        HttpServer::start(
            cfg,
            Arc::new(|req: HttpRequest| {
                if req.path == "/echo" {
                    HttpResponse::text(200, &String::from_utf8_lossy(&req.body))
                } else {
                    HttpResponse::text(404, "nope")
                }
            }),
        )
        .unwrap()
    }

    fn send(addr: SocketAddr, raw: &[u8]) -> (u16, Vec<u8>) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(raw).unwrap();
        read_response(&mut s).unwrap()
    }

    #[test]
    fn serves_requests_and_keep_alive() {
        let server = echo_server(16);
        let addr = server.addr();
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        for i in 0..3 {
            let body = format!("ping{i}");
            let raw = format!(
                "POST /echo HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
                body.len(),
                body
            );
            s.write_all(raw.as_bytes()).unwrap();
            let (status, got) = read_response(&mut s).unwrap();
            assert_eq!(status, 200);
            assert_eq!(got, body.as_bytes());
        }
        let (status, _) = send(addr, b"GET /missing HTTP/1.1\r\nHost: t\r\n\r\n");
        assert_eq!(status, 404);
        server.shutdown();
    }

    #[test]
    fn malformed_gets_400_and_close() {
        let server = echo_server(16);
        let (status, _) = send(server.addr(), b"BROKEN\r\n\r\n");
        assert_eq!(status, 400);
        assert_eq!(server.stats().bad_requests.load(Ordering::Relaxed), 1);
        server.shutdown();
    }

    #[test]
    fn slow_client_is_reaped_by_read_timeout() {
        let server = echo_server(16);
        let mut s = TcpStream::connect(server.addr()).unwrap();
        // half a request, then stall past the 400ms read timeout
        s.write_all(b"POST /echo HTTP/1.1\r\nContent-Le").unwrap();
        std::thread::sleep(Duration::from_millis(700));
        // server must have reaped us; a fresh request still works
        let (status, body) =
            send(server.addr(), b"POST /echo HTTP/1.1\r\nContent-Length: 2\r\n\r\nok");
        assert_eq!((status, body.as_slice()), (200, &b"ok"[..]));
        assert!(server.stats().reaped.load(Ordering::Relaxed) >= 1);
        server.shutdown();
    }

    #[test]
    fn connection_cap_sheds_with_503() {
        let server = echo_server(0); // cap 0: every connection refused
        let (status, _) = send(server.addr(), b"GET /echo HTTP/1.1\r\n\r\n");
        assert_eq!(status, 503);
        assert_eq!(server.stats().over_cap.load(Ordering::Relaxed), 1);
        server.shutdown();
    }

    #[test]
    fn shutdown_stops_accepting() {
        let server = echo_server(16);
        let addr = server.addr();
        server.shutdown();
        // the listener is gone: either refused outright, or accepted by a
        // dead socket that then yields nothing
        match TcpStream::connect(addr) {
            Err(_) => {}
            Ok(mut s) => {
                s.set_read_timeout(Some(Duration::from_millis(300))).unwrap();
                let _ = s.write_all(b"GET /echo HTTP/1.1\r\n\r\n");
                assert!(read_response(&mut s).is_err(), "no one should answer");
            }
        }
    }
}
