//! GPU memory model: Θ budgeting, OOM detection with *actual* generation
//! lengths, and the split-in-two OOM recovery of §III-C.
//!
//! The batcher bounds batches with predicted lengths (Eq. 5); predictions
//! err, so the engine re-checks with ground truth while serving.  An OOM
//! batch is split evenly into two uninsertable halves that re-enter the
//! waiting queue — halving β halves the cache bound.

use crate::batch::wma::mem_bytes;
use crate::batch::Batch;
use crate::config::GpuProfile;

/// Memory accountant for one LLM instance.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Θ — bytes available for KV cache.
    pub theta: u64,
    /// Δ — KV bytes per token.
    pub delta: u64,
}

impl MemoryModel {
    pub fn from_profile(gpu: &GpuProfile) -> Self {
        MemoryModel {
            theta: gpu.theta(),
            delta: gpu.delta_bytes_per_token,
        }
    }

    /// Eq. (5) with predicted lengths — what the batcher enforces.
    pub fn predicted_usage(&self, b: &Batch) -> u64 {
        mem_bytes(b.size(), b.len(), b.predicted_gen_len(), self.delta)
    }

    /// Eq. (5) with TRUE generation lengths — what the device experiences.
    pub fn actual_usage(&self, b: &Batch) -> u64 {
        mem_bytes(b.size(), b.len(), b.true_gen_len(), self.delta)
    }

    /// Would serving this batch to completion exceed Θ?
    pub fn would_oom(&self, b: &Batch) -> bool {
        self.actual_usage(b) > self.theta
    }

    /// Peak cache utilisation of a batch in [0, ∞) (×Θ).
    pub fn utilisation(&self, b: &Batch) -> f64 {
        self.actual_usage(b) as f64 / self.theta.max(1) as f64
    }

    /// OOM recovery (§III-C): split into two uninsertable halves.
    /// Returns the halves; the caller re-queues them.  A singleton batch
    /// cannot be split — it is returned as-is (and must be served with
    /// truncation; with G ≤ G_max and β = 1 the default profile can always
    /// hold one request).
    pub fn split_on_oom(&self, b: Batch, next_id: u64) -> (Batch, Option<Batch>) {
        if b.size() <= 1 {
            return (b, None);
        }
        let (l, r) = b.split(next_id);
        (l, Some(r))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

    fn req(len: u32, gen: u32, pred: u32) -> PredictedRequest {
        PredictedRequest {
            meta: RequestMeta {
                id: 0,
                task: TaskId::Gc,
                store: StoreId::DETACHED,
                instr: u32::MAX,
                user_input_len: len,
                request_len: len,
                gen_len: gen,
                arrival: 0.0,
                span: Span::DETACHED,
                uih: 0,
            },
            predicted_gen_len: pred,
        }
    }

    fn mm() -> MemoryModel {
        MemoryModel {
            theta: 1_000_000,
            delta: 100,
        }
    }

    #[test]
    fn usage_uses_right_lengths() {
        let mut b = Batch::new(0, req(100, 500, 50), 0.0);
        b.requests.push(req(50, 100, 600));
        let m = mm();
        // predicted: β=2, L=100, G'=600 → 2·700·100
        assert_eq!(m.predicted_usage(&b), 2 * 700 * 100);
        // actual: G=500 → 2·600·100
        assert_eq!(m.actual_usage(&b), 2 * 600 * 100);
    }

    #[test]
    fn oom_detection_threshold() {
        let m = mm();
        let b = Batch::new(0, req(4000, 6001, 1), 0.0); // 1·10001·100 > 1e6
        assert!(m.would_oom(&b));
        let ok = Batch::new(0, req(4000, 5999, 1), 0.0);
        assert!(!m.would_oom(&ok));
    }

    #[test]
    fn split_halves_memory_bound() {
        let m = mm();
        let mut b = Batch::new(0, req(100, 4951, 1), 0.0);
        for _ in 0..1 {
            b.requests.push(req(100, 4951, 1));
        }
        assert!(m.would_oom(&b)); // 2·5051·100 > 1e6
        let (l, r) = m.split_on_oom(b, 1);
        let r = r.unwrap();
        assert!(!m.would_oom(&l) && !m.would_oom(&r));
        assert!(!l.insertable && !r.insertable);
    }

    #[test]
    fn singleton_not_split() {
        let m = mm();
        let b = Batch::new(0, req(9000, 9000, 1), 0.0);
        let (same, none) = m.split_on_oom(b, 1);
        assert!(none.is_none());
        assert_eq!(same.size(), 1);
    }

    #[test]
    fn split_preserves_requests_and_reduces_usage() {
        prop_check(100, |rng| {
            let m = MemoryModel {
                theta: 1_000_000,
                delta: 100,
            };
            let n = rng.range_usize(2, 20);
            let mut b = Batch::new(
                0,
                req(rng.range_u64(1, 1000) as u32, rng.range_u64(1, 1000) as u32, 1),
                0.0,
            );
            for _ in 1..n {
                b.requests.push(req(
                    rng.range_u64(1, 1000) as u32,
                    rng.range_u64(1, 1000) as u32,
                    1,
                ));
            }
            let before = m.actual_usage(&b);
            let total = b.size();
            let (l, r) = m.split_on_oom(b, 1);
            let r = r.unwrap();
            assert_eq!(l.size() + r.size(), total);
            assert!(m.actual_usage(&l) <= before);
            assert!(m.actual_usage(&r) <= before);
        });
    }
}
