//! Inference engines: the common interface between the coordinator and
//! the compute substrate.
//!
//! Two implementations:
//! * [`cost::CostModelEngine`] — analytic serving-time model calibrated to
//!   the paper's testbed (V100 + ChatGLM-6B under huggingface-
//!   transformers); drives the discrete-event simulator that regenerates
//!   the paper's figures at full scale.
//! * [`pjrt::PjrtEngine`] — real compute: executes the AOT-compiled JAX/
//!   Pallas artifacts through the PJRT CPU client (prefill + per-iteration
//!   decode with KV cache round-tripping), used by the end-to-end example.
//!
//! [`quantized::QuantizedEngine`] wraps either to model the VSQ baseline.

pub mod cost;
pub mod faulty;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod quantized;

use crate::batch::Batch;

/// Per-request outcome of serving a batch.
#[derive(Debug, Clone)]
pub struct ServedRequest {
    pub request_id: u64,
    /// Tokens generated before (and incl.) EOS — returned to the user.
    pub valid_tokens: u32,
    /// Invalid tokens generated while waiting for batch-mates (§II-D).
    pub invalid_tokens: u32,
}

/// Outcome of serving one batch to completion (or OOM).
#[derive(Debug, Clone)]
pub enum BatchOutcome {
    Completed {
        /// Wall-clock seconds of the batch serving procedure.
        serving_time: f64,
        per_request: Vec<ServedRequest>,
    },
    /// The KV cache exceeded Θ at `at_iteration`; `wasted_time` elapsed
    /// before the error (the worker empties memory and reloads, §III-F).
    Oom {
        at_iteration: u32,
        wasted_time: f64,
    },
}

impl BatchOutcome {
    pub fn is_oom(&self) -> bool {
        matches!(self, BatchOutcome::Oom { .. })
    }
}

/// A compute substrate that can serve padded static batches and expose
/// iteration-level costs (the CCB baseline schedules at iteration
/// granularity).
pub trait InferenceEngine: Send + Sync {
    /// Serve a batch to completion with the §II-D static-batch procedure.
    fn serve_batch(&self, batch: &Batch) -> BatchOutcome;

    /// Cost of one decoding iteration with `beta` parallel requests at
    /// (mean) context length `ctx` tokens.
    fn decode_iter_time(&self, beta: u32, ctx: u32) -> f64;

    /// Cost of the initialisation phase for `beta` requests padded to
    /// `len` tokens.
    fn prefill_time(&self, beta: u32, len: u32) -> f64;

    /// Human-readable engine name for logs/metrics.
    fn name(&self) -> &'static str;
}
