//! Fault-injecting engine wrapper (ISSUE 6): wraps any
//! [`InferenceEngine`] and overlays a [`FaultPlan`]'s injected failures on
//! its nominal outcomes.
//!
//! The wrapper is a *pure observer* of the inner engine: it always runs
//! the nominal serve first, then decides — statelessly, from the plan's
//! seeded hash over `(batch_id, attempt)` — whether that dispatch instead
//! crashed its worker, failed transiently, was killed by a forced-OOM
//! storm, or was merely slowed by an open stall window.  Because every
//! decision hashes coordinates rather than advancing a generator, the
//! same plan replays bit-identically regardless of dispatch interleaving,
//! and a no-op plan adds zero floating-point operations to the nominal
//! path (the caller is expected to branch on
//! [`FaultPlan::is_noop`](crate::faults::FaultPlan::is_noop) and call the
//! inner engine directly for golden-equivalence paths).

use crate::batch::Batch;
use crate::engine::{BatchOutcome, InferenceEngine};
use crate::faults::FaultPlan;

/// What one fault-overlaid dispatch produced.
#[derive(Debug, Clone)]
pub enum InjectedOutcome {
    /// The dispatch ran to an engine outcome (possibly a forced OOM or a
    /// stall-scaled version of the nominal one).  `forced` marks an OOM
    /// the plan injected rather than the engine's own memory model.
    Outcome {
        outcome: BatchOutcome,
        forced: bool,
    },
    /// The worker crashed mid-serve: the batch is lost in-flight and the
    /// instance needs a restart.  `wasted_time` elapsed before the crash.
    Crash { wasted_time: f64 },
    /// The serve call failed transiently (worker survives): the batch
    /// must be retried or shed.  `wasted_time` elapsed before the error.
    TransientError { wasted_time: f64 },
}

/// An [`InferenceEngine`] plus a [`FaultPlan`] overlay.  Borrows both —
/// it is a per-call-site view, not an owner.
pub struct FaultyEngine<'a> {
    inner: &'a dyn InferenceEngine,
    plan: &'a FaultPlan,
}

impl<'a> FaultyEngine<'a> {
    pub fn new(inner: &'a dyn InferenceEngine, plan: &'a FaultPlan) -> FaultyEngine<'a> {
        FaultyEngine { inner, plan }
    }

    /// The wrapped engine, for no-op-plan fast paths that must stay
    /// byte-identical to legacy dispatch.
    pub fn inner(&self) -> &'a dyn InferenceEngine {
        self.inner
    }

    /// Serve `batch` at simulated/replayed time `now`, dispatch number
    /// `attempt` (0 for the first try; retries bump it so each redispatch
    /// redraws its fault decisions).
    pub fn serve_batch_at(&self, now: f64, batch: &Batch, attempt: u64) -> InjectedOutcome {
        let nominal = self.inner.serve_batch(batch);
        let stall = self.plan.stall_factor(now);
        let base = stall
            * match &nominal {
                BatchOutcome::Completed { serving_time, .. } => *serving_time,
                BatchOutcome::Oom { wasted_time, .. } => *wasted_time,
            };
        if self.plan.injects_crash(batch.id, attempt) {
            return InjectedOutcome::Crash {
                wasted_time: base * self.plan.wasted_fraction(batch.id, attempt),
            };
        }
        if self.plan.injects_serve_error(batch.id, attempt) {
            return InjectedOutcome::TransientError {
                wasted_time: base * self.plan.wasted_fraction(batch.id, attempt),
            };
        }
        if !nominal.is_oom() && self.plan.forced_oom(now, batch.id, attempt) {
            // Kill the batch mid-decode: the storm models memory pressure
            // from outside this batch, so the split point is the halfway
            // iteration rather than anything the cost model derived.
            return InjectedOutcome::Outcome {
                outcome: BatchOutcome::Oom {
                    at_iteration: (batch.true_gen_len() / 2).max(1),
                    wasted_time: base * self.plan.wasted_fraction(batch.id, attempt),
                },
                forced: true,
            };
        }
        let outcome = if stall != 1.0 {
            scale_outcome(nominal, stall)
        } else {
            // Bit-exactness: multiplying by 1.0 is a float identity, but
            // skipping the op entirely keeps this path provably inert.
            nominal
        };
        InjectedOutcome::Outcome {
            outcome,
            forced: false,
        }
    }
}

/// Scale an outcome's times by an open stall factor.
fn scale_outcome(outcome: BatchOutcome, factor: f64) -> BatchOutcome {
    match outcome {
        BatchOutcome::Completed {
            serving_time,
            per_request,
        } => BatchOutcome::Completed {
            serving_time: serving_time * factor,
            per_request,
        },
        BatchOutcome::Oom {
            at_iteration,
            wasted_time,
        } => BatchOutcome::Oom {
            at_iteration,
            wasted_time: wasted_time * factor,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::engine::cost::CostModelEngine;
    use crate::faults::{OomStorm, Stall, Window};
    use crate::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

    fn req(id: u64, len: u32, gen: u32, pred: u32, arrival: f64) -> PredictedRequest {
        PredictedRequest {
            meta: RequestMeta {
                id,
                task: TaskId::Gc,
                store: StoreId::DETACHED,
                instr: u32::MAX,
                user_input_len: len.saturating_sub(1),
                request_len: len,
                gen_len: gen,
                arrival,
                span: Span::DETACHED,
                uih: 0,
            },
            predicted_gen_len: pred,
        }
    }

    fn small_batch() -> Batch {
        let mut b = Batch::new(7, req(1, 30, 12, 12, 0.0), 0.2);
        b.requests.push(req(2, 28, 10, 10, 0.1));
        b
    }

    fn engine() -> CostModelEngine {
        let cfg = ServingConfig::default();
        CostModelEngine::new(cfg.cost.clone(), &cfg.gpu)
    }

    #[test]
    fn noop_plan_passes_nominal_outcome_through_bitwise() {
        let eng = engine();
        let plan = FaultPlan::none();
        let faulty = FaultyEngine::new(&eng, &plan);
        let batch = small_batch();
        let nominal = eng.serve_batch(&batch);
        match (faulty.serve_batch_at(3.0, &batch, 0), nominal) {
            (
                InjectedOutcome::Outcome {
                    outcome:
                        BatchOutcome::Completed {
                            serving_time: a, ..
                        },
                    forced: false,
                },
                BatchOutcome::Completed {
                    serving_time: b, ..
                },
            ) => assert_eq!(a.to_bits(), b.to_bits()),
            other => panic!("expected pass-through completion, got {other:?}"),
        }
    }

    #[test]
    fn crash_decisions_are_deterministic_and_redrawn_per_attempt() {
        let eng = engine();
        let mut plan = FaultPlan::none();
        plan.seed = 42;
        plan.crash_p = 0.5;
        let faulty = FaultyEngine::new(&eng, &plan);
        let batch = small_batch();
        let classify = |attempt: u64| -> (bool, u64) {
            match faulty.serve_batch_at(1.0, &batch, attempt) {
                InjectedOutcome::Crash { wasted_time } => (true, wasted_time.to_bits()),
                _ => (false, 0),
            }
        };
        let first: Vec<_> = (0..32).map(classify).collect();
        let second: Vec<_> = (0..32).map(classify).collect();
        assert_eq!(first, second, "same plan must replay bit-identically");
        let crashes = first.iter().filter(|(c, _)| *c).count();
        assert!(crashes > 4 && crashes < 28, "p=0.5 over 32 draws: {crashes}");
    }

    #[test]
    fn stalls_scale_and_storms_force_ooms() {
        let eng = engine();
        let mut plan = FaultPlan::none();
        plan.stalls.push(Stall {
            window: Window::new(0.0, 10.0),
            factor: 3.0,
        });
        plan.oom_storms.push(OomStorm {
            window: Window::new(100.0, 200.0),
            p: 1.0,
        });
        let faulty = FaultyEngine::new(&eng, &plan);
        let batch = small_batch();
        let nominal = match eng.serve_batch(&batch) {
            BatchOutcome::Completed { serving_time, .. } => serving_time,
            other => panic!("cost model should complete: {other:?}"),
        };
        match faulty.serve_batch_at(5.0, &batch, 0) {
            InjectedOutcome::Outcome {
                outcome: BatchOutcome::Completed { serving_time, .. },
                forced: false,
            } => {
                assert_eq!(serving_time.to_bits(), (nominal * 3.0).to_bits());
            }
            other => panic!("expected stalled completion, got {other:?}"),
        }
        match faulty.serve_batch_at(150.0, &batch, 0) {
            InjectedOutcome::Outcome {
                outcome: BatchOutcome::Oom { at_iteration, .. },
                forced: true,
            } => assert_eq!(at_iteration, batch.true_gen_len() / 2),
            other => panic!("expected forced OOM, got {other:?}"),
        }
        // outside every window: byte-identical nominal path
        match faulty.serve_batch_at(50.0, &batch, 0) {
            InjectedOutcome::Outcome {
                outcome: BatchOutcome::Completed { serving_time, .. },
                forced: false,
            } => assert_eq!(serving_time.to_bits(), nominal.to_bits()),
            other => panic!("expected nominal completion, got {other:?}"),
        }
    }
}
