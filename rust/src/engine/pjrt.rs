//! Real-compute batch serving over the PJRT runtime.
//!
//! [`PjrtBatchServer`] executes the §II-D static-batch procedure for real:
//! tokenize, right-pad to the bucket length, one prefill execution, then
//! one decode execution per iteration until the batch generation length is
//! reached, with the KV cache round-tripped through the executable.
//!
//! **EOS injection.**  The tiny model's weights are random, so its own EOS
//! timing is meaningless; the trace's ground-truth generation length says
//! when each request "samples EOS" (DESIGN.md §2).  Compute is real — every
//! iteration runs the full transformer, pad tokens and invalid tokens cost
//! exactly what the paper says they cost — only the stop decision is
//! injected.  Early-finished requests keep generating invalid tokens until
//! the batch completes, as in the paper.
//!
//! This type deliberately does NOT implement [`super::InferenceEngine`]:
//! the PJRT client wraps raw C pointers (`!Send`), so each server worker
//! thread owns its own `PjrtBatchServer` instead of sharing one behind the
//! trait object.

use std::time::Instant;

use anyhow::Result;

use crate::batch::Batch;
use crate::engine::{BatchOutcome, ServedRequest};
use crate::runtime::ModelRuntime;
use crate::tokenizer::Tokenizer;
use crate::workload::TraceStore;

/// One worker's real inference engine.
pub struct PjrtBatchServer {
    rt: ModelRuntime,
    tok: Tokenizer,
}

/// Outcome plus the generated token ids per request (valid prefix only).
pub struct RealOutcome {
    pub outcome: BatchOutcome,
    pub generated: Vec<Vec<u32>>,
}

impl PjrtBatchServer {
    pub fn load(artifacts_dir: &str) -> Result<PjrtBatchServer> {
        Ok(PjrtBatchServer {
            rt: ModelRuntime::load(artifacts_dir)?,
            tok: Tokenizer::new(),
        })
    }

    /// Compile every bucket ahead of serving.
    pub fn warm_up(&mut self) -> Result<()> {
        self.rt.warm_up()
    }

    /// Largest batch the artifacts support.
    pub fn max_batch(&self) -> usize {
        self.rt.manifest.max_batch()
    }

    /// KV-cache capacity in tokens.
    pub fn l_max(&self) -> usize {
        self.rt.manifest.model.l_max
    }

    /// Serve a batch to completion; serving time is wall clock.
    ///
    /// The batch carries compact metas; `store` resolves each request's
    /// instruction/user-input text as borrowed arena slices — the only
    /// copies made here are the token-id buffers the runtime needs.
    pub fn serve(&mut self, batch: &Batch, store: &TraceStore) -> Result<RealOutcome> {
        let t0 = Instant::now();
        let n = batch.requests.len();
        let vocab = self.rt.vocab();

        // Tokenize: instruction ++ user input (BOS from encode()).
        let mut prompts: Vec<Vec<u32>> = Vec::with_capacity(n);
        for r in &batch.requests {
            let mut ids = self.tok.encode(store.instruction(&r.meta));
            ids.extend(self.tok.encode_raw(store.user_input(&r.meta)));
            prompts.push(ids);
        }
        let max_len = prompts.iter().map(|p| p.len()).max().unwrap();
        let bucket_len = self
            .rt
            .manifest
            .prefill_bucket(n, max_len)
            .ok_or_else(|| anyhow::anyhow!("no bucket for {n}x{max_len}"))?
            .len as u32;

        // Per-request generation targets, capped by cache capacity.
        let capacity = (self.l_max() as u32).saturating_sub(bucket_len);
        let targets: Vec<u32> = batch
            .requests
            .iter()
            .map(|r| r.meta.gen_len.min(capacity).max(1))
            .collect();
        let batch_gen = *targets.iter().max().unwrap();

        let lens: Vec<u32> = prompts.iter().map(|p| p.len() as u32).collect();
        let out = self.rt.prefill(&prompts)?;
        let mut logits = out.logits;
        let mut cache = out.cache;

        let mut generated: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut last: Vec<u32> = (0..n)
            .map(|i| ModelRuntime::argmax_row(&logits, vocab, i))
            .collect();
        for i in 0..n {
            generated[i].push(last[i]);
        }

        // Iterations 2..=G(B): one decode execution each (§II-D).
        for g in 1..batch_gen {
            let pos = bucket_len + g - 1;
            let step = self.rt.decode_step(&last, pos, bucket_len, &lens, cache)?;
            logits = step.logits;
            cache = step.cache;
            for i in 0..n {
                last[i] = ModelRuntime::argmax_row(&logits, vocab, i);
                if (generated[i].len() as u32) < batch_gen {
                    generated[i].push(last[i]);
                }
            }
        }

        let per_request: Vec<ServedRequest> = batch
            .requests
            .iter()
            .zip(&targets)
            .map(|(r, &t)| ServedRequest {
                request_id: r.meta.id,
                valid_tokens: t,
                invalid_tokens: batch_gen - t,
            })
            .collect();
        // Truncate each request's output at its injected EOS.
        for (g, &t) in generated.iter_mut().zip(&targets) {
            g.truncate(t as usize);
        }

        Ok(RealOutcome {
            outcome: BatchOutcome::Completed {
                serving_time: t0.elapsed().as_secs_f64(),
                per_request,
            },
            generated,
        })
    }

    /// Decode generated ids to text (for demo output).
    pub fn decode_text(&self, ids: &[u32]) -> String {
        self.tok.decode(ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Request, TaskId};

    fn have_artifacts() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    fn req(id: u64, input: &str, gen: u32) -> Request {
        Request {
            id,
            task: TaskId::Gc,
            instruction: "Fix:".to_string(),
            user_input: input.to_string(),
            user_input_len: input.len() as u32,
            request_len: (input.len() + 6) as u32,
            gen_len: gen,
            arrival: 0.0,
        }
    }

    /// Intern `reqs` and form one batch over the whole store.
    fn batch_of(reqs: &[Request]) -> (TraceStore, Batch) {
        let store = TraceStore::from_requests(reqs);
        let b = Batch::of_store(0, &store);
        (store, b)
    }

    #[test]
    fn serves_real_batch_with_correct_token_accounting() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts/ not built");
            return;
        }
        let mut srv = PjrtBatchServer::load("artifacts").unwrap();
        let (store, b) = batch_of(&[req(0, "abc", 4), req(1, "defgh", 9)]);
        let out = srv.serve(&b, &store).unwrap();
        match out.outcome {
            BatchOutcome::Completed {
                serving_time,
                per_request,
            } => {
                assert!(serving_time > 0.0);
                assert_eq!(per_request[0].valid_tokens, 4);
                assert_eq!(per_request[0].invalid_tokens, 5);
                assert_eq!(per_request[1].valid_tokens, 9);
                assert_eq!(per_request[1].invalid_tokens, 0);
            }
            _ => panic!("unexpected OOM"),
        }
        assert_eq!(out.generated[0].len(), 4);
        assert_eq!(out.generated[1].len(), 9);
    }

    #[test]
    fn generation_deterministic_across_runs() {
        if !have_artifacts() {
            return;
        }
        let mut srv = PjrtBatchServer::load("artifacts").unwrap();
        let (store, b) = batch_of(&[req(0, "hello", 6)]);
        let a = srv.serve(&b, &store).unwrap();
        let c = srv.serve(&b, &store).unwrap();
        assert_eq!(a.generated, c.generated);
    }
}
