//! Analytic cost-model engine (the V100 testbed substitute).
//!
//! Batch serving time follows the structure of §II-D: one initialisation
//! phase over the padded prompts, then G(B) decoding iterations whose cost
//! grows with the KV cache:
//!
//!   T(B) = prefill(β, L) + Σ_{g=1}^{G(B)} iter(β, L+g)
//!        with iter(β, c) = c0 + c1·β + c2·β·c
//!        and  prefill(β, L) = c0 + c3·β·L² + c4·β·L.
//!
//! Constants are calibrated so the paper's Fig. 6 case study reproduces
//! (see `tests::fig6_calibration`): VS serves the 21-request example in
//! ≈242 s, Magnus in ≈60 s.  The closed form below evaluates the iteration
//! sum in O(1), so the simulator can sweep thousands of batches per
//! second.
//!
//! The engine also enforces the memory bound with TRUE generation lengths:
//! if the cache crosses Θ at iteration g* < G(B) the batch OOMs (the
//! coordinator then splits it, §III-C).

use crate::batch::Batch;
use crate::config::{CostModelParams, GpuProfile};
use crate::engine::{BatchOutcome, InferenceEngine, ServedRequest};

/// Analytic engine over the default or a custom profile.
#[derive(Debug, Clone)]
pub struct CostModelEngine {
    pub params: CostModelParams,
    /// Θ in bytes; 0 disables the OOM check (CCB manages memory itself).
    pub theta: u64,
    /// Δ KV bytes per token.
    pub delta: u64,
}

impl CostModelEngine {
    pub fn new(params: CostModelParams, gpu: &GpuProfile) -> Self {
        CostModelEngine {
            params,
            theta: gpu.theta(),
            delta: gpu.delta_bytes_per_token,
        }
    }

    /// Serving time of a completed batch in closed form.
    ///
    /// Σ_{g=1}^{G} (c0 + c1·β + c2·β·(L+g))
    ///   = G·(c0 + c1·β + c2·β·L) + c2·β·G(G+1)/2
    pub fn batch_time(&self, beta: u32, len: u32, gen: u32) -> f64 {
        let p = &self.params;
        let (b, l, g) = (beta as f64, len as f64, gen as f64);
        let decode = g * (p.c0 + p.c1 * b + p.c2 * b * l)
            + p.c2 * b * g * (g + 1.0) / 2.0;
        self.prefill_time(beta, len) + decode
    }

    /// Iteration at which the cache crosses Θ, if within `gen`.
    fn oom_iteration(&self, beta: u32, len: u32, gen: u32) -> Option<u32> {
        if self.theta == 0 {
            return None;
        }
        let cap_tokens = self.theta / (beta as u64 * self.delta);
        if cap_tokens <= len as u64 {
            return Some(1);
        }
        let g_star = (cap_tokens - len as u64) as u32;
        if g_star < gen {
            Some(g_star + 1)
        } else {
            None
        }
    }
}

impl InferenceEngine for CostModelEngine {
    fn serve_batch(&self, batch: &Batch) -> BatchOutcome {
        let beta = batch.size();
        let len = batch.len();
        let gen = batch.true_gen_len();

        if let Some(at) = self.oom_iteration(beta, len, gen) {
            // Time burnt before the OOM: prefill + (at-1) iterations.
            let wasted = self.batch_time(beta, len, at.saturating_sub(1));
            return BatchOutcome::Oom {
                at_iteration: at,
                wasted_time: wasted,
            };
        }

        let serving_time = self.batch_time(beta, len, gen);
        let per_request = batch
            .requests
            .iter()
            .map(|r| ServedRequest {
                request_id: r.meta.id,
                valid_tokens: r.meta.gen_len,
                invalid_tokens: gen - r.meta.gen_len,
            })
            .collect();
        BatchOutcome::Completed {
            serving_time,
            per_request,
        }
    }

    fn decode_iter_time(&self, beta: u32, ctx: u32) -> f64 {
        let p = &self.params;
        p.c0 + p.c1 * beta as f64 + p.c2 * beta as f64 * ctx as f64
    }

    fn prefill_time(&self, beta: u32, len: u32) -> f64 {
        let p = &self.params;
        let (b, l) = (beta as f64, len as f64);
        p.c0 + p.c3 * b * l * l + p.c4 * b * l
    }

    fn name(&self) -> &'static str {
        "cost-model"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::Batch;
    use crate::config::ServingConfig;
    use crate::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

    fn req(id: u64, len: u32, gen: u32) -> PredictedRequest {
        PredictedRequest {
            meta: RequestMeta {
                id,
                task: TaskId::Gc,
                store: StoreId::DETACHED,
                instr: u32::MAX,
                user_input_len: len,
                request_len: len,
                gen_len: gen,
                arrival: 0.0,
                span: Span::DETACHED,
                uih: 0,
            },
            predicted_gen_len: gen,
        }
    }

    fn engine() -> CostModelEngine {
        let cfg = ServingConfig::default();
        CostModelEngine::new(cfg.cost, &cfg.gpu)
    }

    fn batch_of(reqs: Vec<PredictedRequest>) -> Batch {
        let mut it = reqs.into_iter();
        let mut b = Batch::new(0, it.next().unwrap(), 0.0);
        b.requests.extend(it);
        b
    }

    /// Fig. 6 case study: 18 small (L=G≈10) + 3 large (L=G≈1000).
    /// VS: 3 FCFS batches of 7, each containing a large request → ≈242 s.
    /// Magnus: one batch of 18 smalls + one of 3 larges → ≈60 s.
    /// The constants must land in the right *regime* (±35%), and the
    /// improvement ratio must be ≈4× (paper: 75.2% reduction).
    #[test]
    fn fig6_calibration() {
        let e = engine();
        // vanilla: batch of 7 with max L=1000, G=1000
        let vs_batch = e.batch_time(7, 1000, 1000);
        let vs_total = 3.0 * vs_batch;
        // magnus: 18 smalls + 3 larges
        let m_small = e.batch_time(18, 10, 10);
        let m_large = e.batch_time(3, 1000, 1000);
        let m_total = m_small + m_large;
        assert!(
            (vs_total - 242.0).abs() < 242.0 * 0.35,
            "VS total {vs_total:.1}s (paper 242s)"
        );
        assert!(
            (m_total - 60.0).abs() < 60.0 * 0.35,
            "Magnus total {m_total:.1}s (paper 60s)"
        );
        let reduction = 1.0 - m_total / vs_total;
        assert!(
            (reduction - 0.752).abs() < 0.12,
            "reduction {:.1}% (paper 75.2%)",
            reduction * 100.0
        );
    }

    #[test]
    fn invalid_tokens_accounted() {
        let e = engine();
        let b = batch_of(vec![req(0, 10, 5), req(1, 10, 20)]);
        match e.serve_batch(&b) {
            BatchOutcome::Completed { per_request, .. } => {
                assert_eq!(per_request[0].valid_tokens, 5);
                assert_eq!(per_request[0].invalid_tokens, 15);
                assert_eq!(per_request[1].invalid_tokens, 0);
            }
            _ => panic!("unexpected OOM"),
        }
    }

    #[test]
    fn longer_generation_takes_longer() {
        let e = engine();
        assert!(e.batch_time(4, 100, 200) > e.batch_time(4, 100, 100));
        assert!(e.batch_time(8, 100, 100) > e.batch_time(4, 100, 100));
        assert!(e.batch_time(4, 500, 100) > e.batch_time(4, 100, 100));
    }

    #[test]
    fn closed_form_matches_loop() {
        let e = engine();
        for (beta, len, gen) in [(1u32, 8u32, 5u32), (7, 1000, 100), (32, 16, 64)] {
            let loop_sum: f64 = (1..=gen)
                .map(|g| e.decode_iter_time(beta, len + g))
                .sum::<f64>()
                + e.prefill_time(beta, len);
            let closed = e.batch_time(beta, len, gen);
            assert!(
                (loop_sum - closed).abs() < 1e-6 * loop_sum.max(1.0),
                "β={beta} L={len} G={gen}: {loop_sum} vs {closed}"
            );
        }
    }

    #[test]
    fn oom_fires_when_cache_exceeds_theta() {
        let mut e = engine();
        // shrink Θ so a 32×(1000+1000) batch cannot fit
        e.theta = 32 * 1500 * e.delta;
        let b = batch_of((0..32).map(|i| req(i, 1000, 1000)).collect());
        match e.serve_batch(&b) {
            BatchOutcome::Oom { at_iteration, wasted_time } => {
                assert_eq!(at_iteration, 501);
                assert!(wasted_time > 0.0);
            }
            _ => panic!("expected OOM"),
        }
    }

    #[test]
    fn no_oom_when_theta_disabled() {
        let mut e = engine();
        e.theta = 0;
        let b = batch_of((0..64).map(|i| req(i, 1024, 1024)).collect());
        assert!(!e.serve_batch(&b).is_oom());
    }

    #[test]
    fn default_profile_fits_vanilla_batch() {
        // the Eq.1-derived β=7 worst-case batch must NOT oom by construction
        let e = engine();
        let b = batch_of((0..7).map(|i| req(i, 1024, 1024)).collect());
        assert!(!e.serve_batch(&b).is_oom());
    }
}
