//! VSQ substrate: 4-bit weight quantization effects (paper §IV-A/§IV-B).
//!
//! Quantization (a) frees weight memory — the paper exploits it with a
//! larger fixed batch size of 10; (b) adds dequantisation overhead to
//! every iteration (`iter_slowdown`); and (c) degrades generation quality,
//! producing redundant content that inflates generation lengths
//! (`genlen_inflation`) — the paper's CT example generates extra code
//! after the answer.  (b) and (c) are why VSQ loses to plain VS despite
//! its bigger batches.
//!
//! The wrapper inflates every request's generation length and scales all
//! times; inflated tokens are still *returned* tokens (pre-EOS), so they
//! count as valid in token-throughput metrics — matching how the paper's
//! Fig. 10 counts VSQ output.

use crate::batch::Batch;
use crate::config::QuantConfig;
use crate::engine::{BatchOutcome, InferenceEngine, ServedRequest};

/// Wraps an engine with quantization effects.
pub struct QuantizedEngine<E: InferenceEngine> {
    inner: E,
    cfg: QuantConfig,
}

impl<E: InferenceEngine> QuantizedEngine<E> {
    pub fn new(inner: E, cfg: QuantConfig) -> Self {
        QuantizedEngine { inner, cfg }
    }

    fn inflate(&self, g: u32) -> u32 {
        ((g as f64 * self.cfg.genlen_inflation).round() as u32).max(g)
    }

    /// The inflated batch the device actually runs.
    fn inflated_batch(&self, batch: &Batch) -> Batch {
        let mut b = batch.clone();
        for r in &mut b.requests {
            r.meta.gen_len = self.inflate(r.meta.gen_len);
        }
        b
    }
}

impl<E: InferenceEngine> InferenceEngine for QuantizedEngine<E> {
    fn serve_batch(&self, batch: &Batch) -> BatchOutcome {
        let inflated = self.inflated_batch(batch);
        match self.inner.serve_batch(&inflated) {
            BatchOutcome::Completed {
                serving_time,
                per_request,
            } => BatchOutcome::Completed {
                serving_time: serving_time * self.cfg.iter_slowdown,
                per_request: per_request
                    .into_iter()
                    .map(|r| ServedRequest {
                        request_id: r.request_id,
                        // inflated output is returned content → valid
                        valid_tokens: r.valid_tokens,
                        invalid_tokens: r.invalid_tokens,
                    })
                    .collect(),
            },
            BatchOutcome::Oom {
                at_iteration,
                wasted_time,
            } => BatchOutcome::Oom {
                at_iteration,
                wasted_time: wasted_time * self.cfg.iter_slowdown,
            },
        }
    }

    fn decode_iter_time(&self, beta: u32, ctx: u32) -> f64 {
        self.inner.decode_iter_time(beta, ctx) * self.cfg.iter_slowdown
    }

    fn prefill_time(&self, beta: u32, len: u32) -> f64 {
        self.inner.prefill_time(beta, len) * self.cfg.iter_slowdown
    }

    fn name(&self) -> &'static str {
        "quantized"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::engine::cost::CostModelEngine;
    use crate::workload::{PredictedRequest, RequestMeta, Span, StoreId, TaskId};

    fn req(id: u64, len: u32, gen: u32) -> PredictedRequest {
        PredictedRequest {
            meta: RequestMeta {
                id,
                task: TaskId::Gc,
                store: StoreId::DETACHED,
                instr: u32::MAX,
                user_input_len: len,
                request_len: len,
                gen_len: gen,
                arrival: 0.0,
                span: Span::DETACHED,
                uih: 0,
            },
            predicted_gen_len: gen,
        }
    }

    fn engines() -> (CostModelEngine, QuantizedEngine<CostModelEngine>) {
        let cfg = ServingConfig::default();
        let base = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
        let q = QuantizedEngine::new(
            CostModelEngine::new(cfg.cost, &cfg.gpu),
            cfg.quant,
        );
        (base, q)
    }

    #[test]
    fn quantized_is_slower_per_batch() {
        let (base, q) = engines();
        let mut b = Batch::new(0, req(0, 100, 100), 0.0);
        b.requests.push(req(1, 100, 100));
        let t_base = match base.serve_batch(&b) {
            BatchOutcome::Completed { serving_time, .. } => serving_time,
            _ => panic!(),
        };
        let t_q = match q.serve_batch(&b) {
            BatchOutcome::Completed { serving_time, .. } => serving_time,
            _ => panic!(),
        };
        // slower from BOTH the slowdown and the inflated generation
        assert!(t_q > t_base * 1.6, "t_q={t_q} t_base={t_base}");
    }

    #[test]
    fn genlen_inflation_extends_waiting() {
        let (_, q) = engines();
        let mut b = Batch::new(0, req(0, 100, 10), 0.0);
        b.requests.push(req(1, 100, 100));
        match q.serve_batch(&b) {
            BatchOutcome::Completed { per_request, .. } => {
                // short request waits for the INFLATED long one:
                // inflate(100)=125, inflate(10)=round(12.5)=13 → 112
                assert_eq!(per_request[0].invalid_tokens, 125 - 13);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn iter_time_scaled() {
        let (base, q) = engines();
        let cfg = ServingConfig::default();
        let t = base.decode_iter_time(4, 200);
        assert!((q.decode_iter_time(4, 200) - t * cfg.quant.iter_slowdown).abs() < 1e-12);
    }
}
