//! The PJRT model runtime: loads AOT artifacts, compiles them once per
//! (batch, length) bucket, and exposes `prefill` / `decode_step` with the
//! KV cache round-tripped between calls.
//!
//! This is the *only* place the serving stack touches XLA.  Python never
//! runs here — the HLO text was produced once at build time by
//! `python/compile/aot.py`.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::runtime::manifest::Manifest;

/// An in-flight batch's KV cache (device-side state between decode steps,
/// held as host literals — see DESIGN.md §Perf for the buffer-resident
/// optimisation).
pub struct KvCache {
    pub k: xla::Literal,
    pub v: xla::Literal,
    /// Decode bucket batch size the cache was created for.
    pub bucket_batch: usize,
}

/// Result of one prefill / decode call.
pub struct StepOutput {
    /// Next-token logits per request, row-major [bucket_batch × vocab];
    /// only the first `n` rows are meaningful.
    pub logits: Vec<f32>,
    pub cache: KvCache,
}

/// The loaded model: weights + lazily compiled executables.
pub struct ModelRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    /// Device-resident parameter buffers in `param_specs` order.
    /// §Perf: uploading the weights once (instead of re-transferring the
    /// host literals on every call) cut the per-iteration decode latency
    /// by ~35% at β=1 — see EXPERIMENTS.md §Perf L2.
    weight_bufs: Vec<xla::PjRtBuffer>,
    prefill_exes: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
    decode_exes: HashMap<usize, xla::PjRtLoadedExecutable>,
}

impl ModelRuntime {
    /// Load manifest + weights and create the PJRT CPU client.
    /// Executables compile lazily per bucket on first use.
    pub fn load(artifacts_dir: &str) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let host = manifest.read_weights()?;
        let mut weight_bufs = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let n: usize = p.shape.iter().product();
            let start = p.offset / 4;
            let buf = client
                .buffer_from_host_buffer(&host[start..start + n], &p.shape, None)
                .map_err(|e| anyhow!("upload {}: {e:?}", p.name))?;
            weight_bufs.push(buf);
        }
        Ok(ModelRuntime {
            manifest,
            client,
            weight_bufs,
            prefill_exes: HashMap::new(),
            decode_exes: HashMap::new(),
        })
    }

    fn compile(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", file))
    }

    /// Eagerly compile every bucket (server warm-up).
    pub fn warm_up(&mut self) -> Result<()> {
        let prefills: Vec<(usize, usize, String)> = self
            .manifest
            .prefill
            .iter()
            .map(|b| (b.batch, b.len, b.file.clone()))
            .collect();
        for (b, l, file) in prefills {
            if !self.prefill_exes.contains_key(&(b, l)) {
                let exe = self.compile(&file)?;
                self.prefill_exes.insert((b, l), exe);
            }
        }
        let decodes: Vec<(usize, String)> = self
            .manifest
            .decode
            .iter()
            .map(|b| (b.batch, b.file.clone()))
            .collect();
        for (b, file) in decodes {
            if !self.decode_exes.contains_key(&b) {
                let exe = self.compile(&file)?;
                self.decode_exes.insert(b, exe);
            }
        }
        Ok(())
    }

    /// Initialisation phase over right-padded prompts.
    ///
    /// `prompts` are token id rows (BOS included); `n = prompts.len()` must
    /// fit a bucket.  Rows shorter than the bucket length are padded with
    /// PAD; ghost rows (bucket batch > n) get a single BOS token.
    pub fn prefill(&mut self, prompts: &[Vec<u32>]) -> Result<StepOutput> {
        let n = prompts.len();
        anyhow::ensure!(n > 0, "empty prefill batch");
        let max_len = prompts.iter().map(|p| p.len()).max().unwrap();
        let bucket = self
            .manifest
            .prefill_bucket(n, max_len)
            .ok_or_else(|| {
                anyhow!("no prefill bucket for batch {n} len {max_len}")
            })?
            .clone();
        let (bb, bl) = (bucket.batch, bucket.len);
        if !self.prefill_exes.contains_key(&(bb, bl)) {
            let exe = self.compile(&bucket.file)?;
            self.prefill_exes.insert((bb, bl), exe);
        }

        let pad = self.manifest.pad as i32;
        let bos = self.manifest.bos as i32;
        let mut tokens = vec![pad; bb * bl];
        let mut lens = vec![1i32; bb];
        for (i, row) in prompts.iter().enumerate() {
            anyhow::ensure!(row.len() <= bl, "prompt longer than bucket");
            for (j, &t) in row.iter().enumerate() {
                tokens[i * bl + j] = t as i32;
            }
            lens[i] = row.len() as i32;
        }
        // ghost rows: single BOS so attention has one valid key
        for i in n..bb {
            tokens[i * bl] = bos;
        }

        let tokens_buf = self
            .client
            .buffer_from_host_buffer(&tokens, &[bb, bl], None)
            .map_err(|e| anyhow!("{e:?}"))?;
        let lens_buf = self
            .client
            .buffer_from_host_buffer(&lens, &[bb], None)
            .map_err(|e| anyhow!("{e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = vec![&tokens_buf, &lens_buf];
        args.extend(self.weight_bufs.iter());

        let exe = &self.prefill_exes[&(bb, bl)];
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow!("prefill execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (logits, k, v) = result.to_tuple3().map_err(|e| anyhow!("{e:?}"))?;
        Ok(StepOutput {
            logits: logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            cache: KvCache {
                k,
                v,
                bucket_batch: bb,
            },
        })
    }

    /// One decoding iteration.
    ///
    /// `tokens` holds the last sampled token per live request (first `n`
    /// rows of the bucket); `pos` is the shared cache slot for the new
    /// KV entries; `l0` the padded prompt length; `lens` the per-request
    /// valid prompt lengths.
    pub fn decode_step(
        &mut self,
        tokens: &[u32],
        pos: u32,
        l0: u32,
        lens: &[u32],
        cache: KvCache,
    ) -> Result<StepOutput> {
        let n = tokens.len();
        let bb = cache.bucket_batch;
        anyhow::ensure!(n <= bb, "decode batch exceeds cache bucket");
        anyhow::ensure!(
            (pos as usize) < self.manifest.model.l_max,
            "decode position {pos} beyond cache capacity {}",
            self.manifest.model.l_max
        );
        let file = self
            .manifest
            .decode
            .iter()
            .find(|d| d.batch == bb)
            .ok_or_else(|| anyhow!("no decode bucket of batch {bb}"))?
            .file
            .clone();
        if !self.decode_exes.contains_key(&bb) {
            let exe = self.compile(&file)?;
            self.decode_exes.insert(bb, exe);
        }

        let bos = self.manifest.bos as i32;
        let mut tok = vec![bos; bb];
        let mut lens_i = vec![1i32; bb];
        for i in 0..n {
            tok[i] = tokens[i] as i32;
            lens_i[i] = lens[i] as i32;
        }

        let up = |data: &[i32], dims: &[usize]| {
            self.client
                .buffer_from_host_buffer(data, dims, None)
                .map_err(|e| anyhow!("{e:?}"))
        };
        let tok_buf = up(&tok, &[bb])?;
        let pos_buf = up(&[pos as i32], &[])?;
        let l0_buf = up(&[l0 as i32], &[])?;
        let lens_buf = up(&lens_i, &[bb])?;
        let k_buf = self
            .client
            .buffer_from_host_literal(None, &cache.k)
            .map_err(|e| anyhow!("{e:?}"))?;
        let v_buf = self
            .client
            .buffer_from_host_literal(None, &cache.v)
            .map_err(|e| anyhow!("{e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> =
            vec![&tok_buf, &pos_buf, &l0_buf, &lens_buf, &k_buf, &v_buf];
        args.extend(self.weight_bufs.iter());

        let exe = &self.decode_exes[&bb];
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow!("decode execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{e:?}"))?;
        let (logits, k, v) = result.to_tuple3().map_err(|e| anyhow!("{e:?}"))?;
        Ok(StepOutput {
            logits: logits.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            cache: KvCache {
                k,
                v,
                bucket_batch: bb,
            },
        })
    }

    /// Greedy sampling over one logits row.
    pub fn argmax_row(logits: &[f32], vocab: usize, row: usize) -> u32 {
        let s = &logits[row * vocab..(row + 1) * vocab];
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &x) in s.iter().enumerate() {
            if x > bv {
                bv = x;
                best = i;
            }
        }
        best as u32
    }

    pub fn vocab(&self) -> usize {
        self.manifest.model.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<ModelRuntime> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(ModelRuntime::load("artifacts").unwrap())
    }

    #[test]
    fn prefill_shapes_and_finite_logits() {
        let Some(mut rt) = runtime() else { return };
        let prompts = vec![vec![1, 60, 61, 62], vec![1, 70]];
        let out = rt.prefill(&prompts).unwrap();
        let vocab = rt.vocab();
        assert!(out.logits.len() >= 2 * vocab);
        assert!(out.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_roundtrips_cache_and_changes_logits() {
        let Some(mut rt) = runtime() else { return };
        let prompts = vec![vec![1, 50, 51]];
        let bl = rt.manifest.prefill_bucket(1, 3).unwrap().len as u32;
        let out = rt.prefill(&prompts).unwrap();
        let vocab = rt.vocab();
        let t0 = ModelRuntime::argmax_row(&out.logits, vocab, 0);
        let step = rt
            .decode_step(&[t0], bl, bl, &[3], out.cache)
            .unwrap();
        assert!(step.logits.iter().all(|x| x.is_finite()));
        let t1 = ModelRuntime::argmax_row(&step.logits, vocab, 0);
        // stepping again from the new cache must be legal
        let step2 = rt
            .decode_step(&[t1], bl + 1, bl, &[3], step.cache)
            .unwrap();
        assert!(step2.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefill_deterministic() {
        let Some(mut rt) = runtime() else { return };
        let prompts = vec![vec![1, 42, 43, 44, 45]];
        let a = rt.prefill(&prompts).unwrap();
        let b = rt.prefill(&prompts).unwrap();
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn ghost_rows_do_not_affect_real_rows() {
        // batch of 1 padded into a larger bucket must match a pure batch-1 run
        let Some(mut rt) = runtime() else { return };
        if rt.manifest.decode.len() < 2 {
            return; // need at least two batch buckets
        }
        let vocab = rt.vocab();
        let p = vec![1u32, 33, 34];
        let a = rt.prefill(&[p.clone()]).unwrap();
        let bigger = rt.manifest.decode[1].batch;
        let two = vec![p.clone(); bigger];
        let b = rt.prefill(&two).unwrap();
        let ra = &a.logits[..vocab];
        let rb = &b.logits[..vocab];
        for (x, y) in ra.iter().zip(rb) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }
}
