//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it) and the Rust runtime (which loads it).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// Model architecture as recorded at AOT time.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub l_max: usize,
    pub kv_bytes_per_token: u64,
}

/// One serialized parameter tensor in weights.bin.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

/// A compiled prefill bucket (batch, padded length).
#[derive(Debug, Clone)]
pub struct PrefillBucket {
    pub batch: usize,
    pub len: usize,
    pub file: String,
}

/// A compiled decode bucket (batch).
#[derive(Debug, Clone)]
pub struct DecodeBucket {
    pub batch: usize,
    pub file: String,
}

/// Parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub pad: u32,
    pub bos: u32,
    pub eos: u32,
    pub weights_file: String,
    pub params: Vec<ParamSpec>,
    pub prefill: Vec<PrefillBucket>,
    pub decode: Vec<DecodeBucket>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;

        let m = j.get("model");
        let need = |k: &str| -> Result<usize> {
            m.get(k)
                .as_usize()
                .ok_or_else(|| anyhow!("manifest: missing model.{k}"))
        };
        let model = ModelInfo {
            vocab: need("vocab")?,
            d_model: need("d_model")?,
            n_layers: need("n_layers")?,
            n_heads: need("n_heads")?,
            d_head: need("d_head")?,
            d_ff: need("d_ff")?,
            l_max: need("l_max")?,
            kv_bytes_per_token: m
                .get("kv_bytes_per_token")
                .as_u64()
                .ok_or_else(|| anyhow!("manifest: missing kv_bytes_per_token"))?,
        };

        let params = j
            .path("weights.params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: missing weights.params"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p
                        .get("name")
                        .as_str()
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .as_arr()
                        .ok_or_else(|| anyhow!("param missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: p.get("offset").as_usize().unwrap_or(0),
                    bytes: p.get("bytes").as_usize().unwrap_or(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let prefill = j
            .get("prefill")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|b| PrefillBucket {
                batch: b.get("batch").as_usize().unwrap_or(1),
                len: b.get("len").as_usize().unwrap_or(16),
                file: b.get("file").as_str().unwrap_or("").to_string(),
            })
            .collect();
        let decode = j
            .get("decode")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|b| DecodeBucket {
                batch: b.get("batch").as_usize().unwrap_or(1),
                file: b.get("file").as_str().unwrap_or("").to_string(),
            })
            .collect();

        Ok(Manifest {
            model,
            pad: j.path("specials.pad").as_u64().unwrap_or(0) as u32,
            bos: j.path("specials.bos").as_u64().unwrap_or(1) as u32,
            eos: j.path("specials.eos").as_u64().unwrap_or(2) as u32,
            weights_file: j
                .path("weights.file")
                .as_str()
                .unwrap_or("weights.bin")
                .to_string(),
            params,
            prefill,
            decode,
            dir,
        })
    }

    /// Read weights.bin as host f32 data.
    pub fn read_weights(&self) -> Result<Vec<f32>> {
        let raw = std::fs::read(self.dir.join(&self.weights_file))
            .with_context(|| format!("reading {}", self.weights_file))?;
        anyhow::ensure!(raw.len() % 4 == 0, "weights.bin not f32-aligned");
        let mut out = Vec::with_capacity(raw.len() / 4);
        for chunk in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }
        Ok(out)
    }

    /// Smallest prefill bucket with batch ≥ `n` and len ≥ `l`, if any.
    pub fn prefill_bucket(&self, n: usize, l: usize) -> Option<&PrefillBucket> {
        self.prefill
            .iter()
            .filter(|b| b.batch >= n && b.len >= l)
            .min_by_key(|b| (b.batch, b.len))
    }

    /// Smallest decode bucket with batch ≥ `n`, if any.
    pub fn decode_bucket(&self, n: usize) -> Option<&DecodeBucket> {
        self.decode
            .iter()
            .filter(|b| b.batch >= n)
            .min_by_key(|b| b.batch)
    }

    /// Max batch any bucket supports.
    pub fn max_batch(&self) -> usize {
        self.decode.iter().map(|b| b.batch).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let p = PathBuf::from("artifacts");
        if p.join("manifest.json").exists() {
            Some(p)
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model.vocab >= 256 + 3);
        assert_eq!(m.model.d_head * m.model.n_heads, m.model.d_model);
        assert!(!m.params.is_empty());
        assert_eq!(m.params[0].name, "embed");
        assert!(!m.prefill.is_empty() && !m.decode.is_empty());
        // weights file matches the param table extent
        let total: usize = m.params.iter().map(|p| p.bytes).sum();
        let w = m.read_weights().unwrap();
        assert_eq!(w.len() * 4, total);
    }

    #[test]
    fn bucket_selection_picks_smallest_fit() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let b = m.decode_bucket(1).unwrap();
        assert_eq!(b.batch, m.decode.iter().map(|d| d.batch).min().unwrap());
        assert!(m.decode_bucket(m.max_batch() + 1).is_none());
        if let Some(pb) = m.prefill_bucket(1, 1) {
            assert!(pb.batch >= 1 && pb.len >= 1);
        }
    }
}
