//! PJRT runtime: loads the HLO-text artifacts produced by the build-time
//! JAX/Pallas compile path and executes them on the PJRT CPU client.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids — see /opt/xla-example/README.md.

pub mod manifest;
pub mod model;

pub use manifest::Manifest;
pub use model::{KvCache, ModelRuntime, StepOutput};
