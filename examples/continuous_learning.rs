//! Fig. 14 demo: watch the two learned components improve online.
//!
//! Starts Magnus with a deliberately tiny predictor training set and an
//! untrained serving-time estimator, serves a long workload, and prints
//! the windowed RMSE of both predictors over time — the §III-B/§III-D
//! continuous-learning loops should drive both curves down.
//!
//! Run: cargo run --release --example continuous_learning

use magnus::config::ServingConfig;
use magnus::sim::{run_policy, Policy};
use magnus::workload::{generate_trace, TraceSpec};

fn windowed_rmse(errors: &[(f64, f64)], window: f64) -> Vec<(f64, f64)> {
    let mut out = Vec::new();
    if errors.is_empty() {
        return out;
    }
    let t_end = errors.iter().map(|e| e.0).fold(0.0, f64::max);
    let mut t = window;
    while t <= t_end + window {
        let sq: Vec<f64> = errors
            .iter()
            .filter(|(at, _)| *at > t - window && *at <= t)
            .map(|(_, e)| e * e)
            .collect();
        if sq.len() >= 5 {
            out.push((t, (sq.iter().sum::<f64>() / sq.len() as f64).sqrt()));
        }
        t += window;
    }
    out
}

fn bar(x: f64, max: f64) -> String {
    let n = ((x / max) * 50.0).round() as usize;
    "#".repeat(n.min(50))
}

fn main() {
    let mut cfg = ServingConfig::default();
    cfg.learning.predictor_period_s = 60.0;
    cfg.learning.estimator_period_s = 40.0;
    let trace = generate_trace(&TraceSpec {
        rate: 8.0,
        n_requests: 2500,
        seed: 7,
        ..Default::default()
    });
    println!(
        "serving {} requests at λ=8/s with a 40-request/task initial train set …",
        trace.len()
    );
    let out = run_policy(&cfg, Policy::Magnus, &trace, 40);

    println!("\nFig 14a — generation-length predictor RMSE (tokens), 60 s windows:");
    let pred = windowed_rmse(&out.pred_errors, 60.0);
    let max = pred.iter().map(|p| p.1).fold(0.0, f64::max);
    for (t, e) in &pred {
        println!("  t={t:5.0}s  {e:7.2}  {}", bar(*e, max));
    }

    println!("\nFig 14b — serving-time estimator RMSE (seconds), 60 s windows:");
    let est = windowed_rmse(&out.est_errors, 60.0);
    let max = est.iter().map(|p| p.1).fold(0.0, f64::max);
    for (t, e) in &est {
        println!("  t={t:5.0}s  {e:7.2}  {}", bar(*e, max));
    }

    let (first, last) = (pred.first().unwrap().1, pred.last().unwrap().1);
    println!(
        "\npredictor RMSE: {first:.1} → {last:.1} tokens ({:+.0}%)",
        100.0 * (last / first - 1.0)
    );
    let (first, last) = (est.first().unwrap().1, est.last().unwrap().1);
    println!(
        "estimator RMSE: {first:.1} → {last:.1} s ({:+.0}%)",
        100.0 * (last / first - 1.0)
    );
}
