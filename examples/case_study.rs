//! Fig. 6 case study, reproduced on BOTH engines.
//!
//! The paper's motivating example: 21 requests — 18 "small" (L = G ≈ 10)
//! and 3 "large" (L = G ≈ 1000) — arrive interleaved.  Vanilla scheduling
//! packs them FCFS into 3 batches of 7 (each poisoned by a large request);
//! Magnus groups 18 smalls into one batch and 3 larges into another.
//!
//! Engine 1: the V100-calibrated cost model at the paper's full scale
//!           (expect ≈242 s vs ≈60 s, a 75% reduction).
//! Engine 2: real PJRT compute with the tiny model at 1/25 scale
//!           (L = G ≈ 4 / 160) — same *shape*, wall-clock measured.
//!
//! Run: cargo run --release --example case_study

use magnus::batch::{AdaptiveBatcher, Batch, BatcherConfig};
use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::engine::pjrt::PjrtBatchServer;
use magnus::engine::{BatchOutcome, InferenceEngine};
use magnus::workload::{PredictedRequest, Request, TaskId, TraceStore};

fn mk(id: u64, l: u32, g: u32) -> Request {
    // text sized so the byte tokenizer yields ≈ l tokens
    let input = "x".repeat(l.saturating_sub(1) as usize);
    Request {
        id,
        task: TaskId::Gc,
        instruction: String::new(),
        user_input: input,
        user_input_len: l,
        request_len: l,
        gen_len: g,
        arrival: 0.0,
    }
}

/// Fig. 6a arrival order: 6 small, 1 large, repeated three times.
/// Texts intern into a store; the pipeline records are compact metas.
fn arrivals(small: (u32, u32), large: (u32, u32)) -> (TraceStore, Vec<PredictedRequest>) {
    let mut v = Vec::new();
    let mut id = 0;
    for _ in 0..3 {
        for _ in 0..6 {
            v.push(mk(id, small.0, small.1));
            id += 1;
        }
        v.push(mk(id, large.0, large.1));
        id += 1;
    }
    let store = TraceStore::from_requests(&v);
    let preds = store
        .metas()
        .iter()
        .map(|&meta| PredictedRequest {
            meta,
            predicted_gen_len: meta.gen_len,
        })
        .collect();
    (store, preds)
}

fn vanilla_batches(reqs: &[PredictedRequest], beta: usize) -> Vec<Batch> {
    reqs.chunks(beta)
        .enumerate()
        .map(|(i, chunk)| {
            let mut it = chunk.iter().cloned();
            let mut b = Batch::new(i as u64, it.next().unwrap(), 0.0);
            b.requests.extend(it);
            b
        })
        .collect()
}

fn magnus_batches(reqs: Vec<PredictedRequest>, cfg: &ServingConfig) -> Vec<Batch> {
    let mut batcher = AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: cfg.wma_threshold,
        theta: cfg.gpu.theta(),
        delta: cfg.gpu.delta_bytes_per_token,
        max_batch_size: 0,
    });
    for r in reqs {
        batcher.insert(r, 0.0);
    }
    let mut out = Vec::new();
    while !batcher.is_empty() {
        out.push(batcher.take(0));
    }
    out
}

fn main() -> anyhow::Result<()> {
    let cfg = ServingConfig::default();

    // ── Engine 1: cost model at paper scale ────────────────────────────
    println!("── cost-model engine (V100 + ChatGLM-6B scale) ──");
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let (_store, reqs) = arrivals((10, 10), (1000, 1000));

    let serve_all = |batches: &[Batch]| -> f64 {
        batches
            .iter()
            .map(|b| match engine.serve_batch(b) {
                BatchOutcome::Completed { serving_time, .. } => serving_time,
                _ => f64::NAN,
            })
            .sum()
    };
    let vs_total = serve_all(&vanilla_batches(&reqs, 7));
    let mbatches = magnus_batches(reqs, &cfg);
    let m_total = serve_all(&mbatches);
    println!("vanilla : 3 batches of 7          → {vs_total:6.1}s   (paper 242s)");
    println!(
        "magnus  : {}   → {m_total:6.1}s   (paper 60s)",
        mbatches
            .iter()
            .map(|b| format!("β={}", b.size()))
            .collect::<Vec<_>>()
            .join(" + ")
    );
    println!(
        "reduction {:.1}%  (paper 75.2%)\n",
        100.0 * (1.0 - m_total / vs_total)
    );

    // ── Engine 2: real PJRT compute at 1/25 scale ──────────────────────
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(skipping real-compute engine: run `make artifacts` first)");
        return Ok(());
    }
    println!("── real PJRT engine (tiny model, L=G≈4/160, wall clock) ──");
    let mut srv = PjrtBatchServer::load("artifacts")?;
    let (store, reqs) = arrivals((4, 4), (160, 60)); // 160+60 fits the 256 cache
    let mut serve_real = |batches: &[Batch]| -> anyhow::Result<f64> {
        let mut total = 0.0;
        for b in batches {
            match srv.serve(b, &store)?.outcome {
                BatchOutcome::Completed { serving_time, .. } => total += serving_time,
                _ => {}
            }
        }
        Ok(total)
    };
    // vanilla β=4 (scaled from 7 to the artifact buckets)
    let vs_real = serve_real(&vanilla_batches(&reqs, 4))?;
    let mb = magnus_batches(reqs, &cfg);
    let m_real = serve_real(&mb)?;
    println!("vanilla : {} batches of ≤4        → {vs_real:6.2}s wall", (21 + 3) / 4);
    println!(
        "magnus  : {}  → {m_real:6.2}s wall",
        mb.iter()
            .map(|b| format!("β={}", b.size()))
            .collect::<Vec<_>>()
            .join(" + ")
    );
    println!("reduction {:.1}%", 100.0 * (1.0 - m_real / vs_real));
    Ok(())
}
