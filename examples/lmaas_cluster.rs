//! END-TO-END VALIDATION (DESIGN.md §4): serve a real multi-application
//! Poisson workload on a REAL model through the full three-layer stack —
//! Rust coordinator → AOT-compiled JAX model → Pallas attention kernels —
//! and compare Magnus against vanilla scheduling on the same trace.
//!
//! Every decode iteration executes the tiny transformer through PJRT; the
//! coordinator (predictor, WMA batcher, estimator, HRRN) is byte-for-byte
//! the same code the simulator uses.  Results are recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Requires `make artifacts`.  Run:
//!   cargo run --release --example lmaas_cluster [-- --requests 48 --workers 2]

use magnus::config::ServingConfig;
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::server::{serve_trace, LivePolicy, ServeOptions};
use magnus::sim::MagnusPolicy;
use magnus::util::cli::Args;
use magnus::workload::dataset::build_predictor_split;
use magnus::workload::{generate_trace, LlmProfile, TraceSpec};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(&[]).map_err(anyhow::Error::msg)?;
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }

    let n_requests = args.get_usize("requests", 48);
    let n_workers = args.get_usize("workers", 2);
    let rate = args.get_f64("rate", 8.0);
    let time_scale = args.get_f64("time-scale", 20.0);

    // The tiny model's KV cache holds 256 tokens, so the workload is
    // scaled: inputs ≤ 40 tokens, generations ≤ 24 tokens.  The serving
    // *dynamics* (padding, request waiting, batching, scheduling) are
    // identical in kind to the full-scale simulator runs.
    let g_max = 24u32;
    let l_cap = 40u32;
    let mut cfg = ServingConfig::default();
    cfg.gpu.g_max = g_max;

    let trace = generate_trace(&TraceSpec {
        rate,
        n_requests,
        g_max,
        l_cap,
        seed: 11,
        ..Default::default()
    });
    println!(
        "trace: {} requests over {:.1}s at λ={rate}/s (replayed {time_scale}× speed)",
        trace.len(),
        trace.last().unwrap().arrival
    );

    // Train the predictor on a matching held-out split.
    let split = build_predictor_split(LlmProfile::ChatGlm6B, 200, 5, g_max, 12);
    let mut predictor = GenLenPredictor::new(Variant::Usin, &cfg);
    predictor.train(&split.train);

    println!("\n── Magnus (predict → WMA batch → HRRN) on real PJRT compute ──");
    let t0 = std::time::Instant::now();
    let magnus = serve_trace(
        &cfg,
        &ServeOptions {
            artifacts_dir: "artifacts".into(),
            n_workers,
            time_scale,
            warm_up: false,
            ..Default::default()
        },
        LivePolicy::Magnus(MagnusPolicy::magnus()),
        Some(predictor),
        &trace,
    )?;
    let magnus_wall = t0.elapsed().as_secs_f64();

    println!("\n── Vanilla scheduling (FCFS, fixed β=4) on the same trace ──");
    let t0 = std::time::Instant::now();
    let vanilla = serve_trace(
        &cfg,
        &ServeOptions {
            artifacts_dir: "artifacts".into(),
            n_workers,
            time_scale,
            warm_up: false,
            ..Default::default()
        },
        LivePolicy::Vanilla { fixed_batch: 4 },
        None,
        &trace,
    )?;
    let vanilla_wall = t0.elapsed().as_secs_f64();

    let ms = magnus.summarise();
    let vs = vanilla.summarise();
    println!("\n== end-to-end results (times in replayed seconds) ==");
    println!(
        "{:8} | {:>9} | {:>9} | {:>8} | {:>9} | {:>9}",
        "policy", "thr req/s", "mean RT", "p95 RT", "tok/s", "valid/s"
    );
    for (name, s, wall) in [("Magnus", &ms, magnus_wall), ("VS", &vs, vanilla_wall)] {
        println!(
            "{:8} | {:9.3} | {:8.2}s | {:7.2}s | {:9.1} | {:9.1}   (wall {:.1}s)",
            name,
            s.request_throughput,
            s.mean_response_time,
            s.p95_response_time,
            s.token_throughput,
            s.valid_token_throughput,
            wall
        );
    }
    println!(
        "\nMagnus vs VS: mean RT {:+.1}%, request throughput {:+.1}%",
        100.0 * (ms.mean_response_time / vs.mean_response_time - 1.0),
        100.0 * (ms.request_throughput / vs.request_throughput - 1.0),
    );
    Ok(())
}
