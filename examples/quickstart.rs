//! Quickstart: the Magnus pipeline on one page.
//!
//! Trains the generation-length predictor, batches a handful of requests
//! with the WMA-directed adaptive batcher, schedules them with HRRN, and
//! serves them on the calibrated cost-model engine — printing each
//! decision the coordinator makes.
//!
//! Run: `cargo run --release --example quickstart`

use magnus::batch::{AdaptiveBatcher, BatcherConfig};
use magnus::config::ServingConfig;
use magnus::engine::cost::CostModelEngine;
use magnus::engine::{BatchOutcome, InferenceEngine};
use magnus::estimator::{BatchShape, ServingTimeEstimator};
use magnus::predictor::{GenLenPredictor, Variant};
use magnus::scheduler::{select, view_of};
use magnus::workload::dataset::build_predictor_split;
use magnus::workload::{generate_trace, LlmProfile, PredictedRequest, RequestMeta, TraceSpec};

fn main() {
    let cfg = ServingConfig::default();

    // 1. Train the generation-length predictor (paper §III-B) on the
    //    held-out split, as the paper does before serving.
    println!("training USIN predictor …");
    let split = build_predictor_split(LlmProfile::ChatGlm6B, 300, 10, cfg.gpu.g_max, 1);
    let mut predictor = GenLenPredictor::new(Variant::Usin, &cfg);
    predictor.train(&split.train);

    // 2. A burst of 12 mixed requests.
    let trace = generate_trace(&TraceSpec {
        rate: 50.0,
        n_requests: 12,
        seed: 3,
        ..Default::default()
    });

    // 3. Predict + batch (Algorithm 1).
    let mut batcher = AdaptiveBatcher::new(BatcherConfig {
        wma_threshold: cfg.wma_threshold,
        theta: cfg.gpu.theta(),
        delta: cfg.gpu.delta_bytes_per_token,
        max_batch_size: 0,
    });
    for req in &trace {
        let predicted = predictor.predict(req);
        println!(
            "request {:2} [{:9}] L={:4} G'={:4} (true G={:4})",
            req.id,
            req.task.name(),
            req.request_len,
            predicted,
            req.gen_len
        );
        batcher.insert(
            PredictedRequest {
                meta: RequestMeta::detached(req),
                predicted_gen_len: predicted,
            },
            req.arrival,
        );
    }
    println!("\nbatcher formed {} batches:", batcher.queue_len());
    for b in batcher.queue() {
        println!(
            "  batch {}: β={} L(B)={} G'(B)={}",
            b.id,
            b.size(),
            b.len(),
            b.predicted_gen_len()
        );
    }

    // 4. Schedule with HRRN and serve on the cost-model engine.
    let engine = CostModelEngine::new(cfg.cost.clone(), &cfg.gpu);
    let estimator = ServingTimeEstimator::new(cfg.knn_k);
    let now = trace.last().unwrap().arrival + 1.0;
    println!("\nserving in HRRN order:");
    while !batcher.is_empty() {
        let views: Vec<_> = batcher
            .queue()
            .iter()
            .map(|b| {
                let est = estimator.estimate(&BatchShape {
                    batch_size: b.size(),
                    batch_len: b.len(),
                    batch_gen_len: b.predicted_gen_len(),
                });
                view_of(b, now, est)
            })
            .collect();
        let pick = select(cfg.sched, &views).unwrap();
        let batch = batcher.take(pick);
        match engine.serve_batch(&batch) {
            BatchOutcome::Completed {
                serving_time,
                per_request,
            } => {
                let invalid: u32 = per_request.iter().map(|r| r.invalid_tokens).sum();
                println!(
                    "  served batch {} (β={}) in {:6.1}s — {} invalid tokens",
                    batch.id,
                    batch.size(),
                    serving_time,
                    invalid
                );
            }
            BatchOutcome::Oom { .. } => println!("  batch {} OOMed", batch.id),
        }
    }
    println!("\ndone — see examples/lmaas_cluster.rs for the live PJRT path.");
}
